//! Tensor layout + borrowed views: the zero-copy half of the store.
//!
//! [`ModelLayout`] is parsed **once** per archive from the section-A
//! bytes: it records every tensor's name, shape, and the *byte ranges*
//! of its scales / packed payloads — in section A, and (computed from
//! shape arithmetic, no section-B bytes needed) in section B. Views then
//! decode packed words straight from the shared `Arc<[u8]>` sections:
//! no `Container`, no per-tensor word `Vec`s, no copies until the
//! final dequantized f32s.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::bits::{self, packed_nwords, PackedTensor};
use crate::container::{Cursor, Kind, SectionIndex};

use super::Bytes;

/// Byte range of one packed block: `u8 bits | u32 n_words | u64×n_words`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PackedRange {
    bits: u8,
    count: usize,
    /// Offset of the `bits` byte within its section.
    start: usize,
    /// Whole block length (5 + 8·n_words).
    len: usize,
}

impl PackedRange {
    fn words(&self) -> Range<usize> {
        self.start + 5..self.start + self.len
    }
}

/// Where one tensor's payload bytes live.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Payload {
    /// FP32 values in section A.
    Fp32 { values: Range<usize> },
    /// Quantized: scales + packed block in section A, plus (nest only)
    /// the computed `w_low` block in section B.
    Quant {
        scales: Range<usize>,
        packed: PackedRange,
        low: Option<PackedRange>,
    },
}

/// One tensor's metadata + byte ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorLayout {
    name: String,
    shape: Vec<usize>,
    count: usize,
    payload: Payload,
}

impl TensorLayout {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self.payload, Payload::Quant { .. })
    }

    /// Packed bits of the section-A payload (`h` for nest, `n` for
    /// mono), `None` for fp32 tensors.
    pub fn packed_bits(&self) -> Option<u8> {
        match &self.payload {
            Payload::Quant { packed, .. } => Some(packed.bits),
            Payload::Fp32 { .. } => None,
        }
    }

    /// Section-B block bytes of this tensor (0 for fp32 / mono).
    pub fn low_block_bytes(&self) -> usize {
        match &self.payload {
            Payload::Quant { low: Some(l), .. } => l.len,
            _ => 0,
        }
    }
}

/// The parsed-once metadata of one archive: header fields + per-tensor
/// byte ranges. Everything a view needs; none of the payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelLayout {
    kind: Kind,
    n: u8,
    h: u8,
    act_bits: u8,
    name: String,
    meta: String,
    section_b_offset: u64,
    a_len: usize,
    b_len: usize,
    tensors: Vec<TensorLayout>,
}

impl ModelLayout {
    pub fn kind(&self) -> Kind {
        self.kind
    }

    pub fn n(&self) -> u8 {
        self.n
    }

    pub fn h(&self) -> u8 {
        self.h
    }

    pub fn act_bits(&self) -> u8 {
        self.act_bits
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn meta(&self) -> &str {
        &self.meta
    }

    /// Byte offset of section B within the artifact (== section-A
    /// length for nest containers).
    pub fn section_b_offset(&self) -> u64 {
        self.section_b_offset
    }

    /// Total section-B bytes implied by the layout.
    pub fn section_b_bytes(&self) -> u64 {
        self.b_len as u64
    }

    pub fn tensors(&self) -> &[TensorLayout] {
        &self.tensors
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Parse section-A bytes into a layout, cross-checked against the
    /// source's [`SectionIndex`]. Walks metadata only — payload bytes
    /// are *skipped*, never copied.
    pub(crate) fn parse(a: &[u8], index: &SectionIndex) -> Result<ModelLayout> {
        ensure!(
            a.len() as u64 == index.section_a_bytes(),
            "section A is {} bytes, index says {}",
            a.len(),
            index.section_a_bytes()
        );
        // the one header decoder, shared with probe/parse
        let p = crate::container::parse_prefix(a)?;
        let mut c = Cursor { d: a, o: p.consumed };
        let (kind, n, h, act_bits) = (p.kind, p.n, p.h, p.act_bits);
        let (name, meta) = (p.name, p.meta);
        let num = p.num_tensors;
        let off_b = p.section_b_offset;
        ensure!(
            kind == index.kind && n == index.n && h == index.h,
            "header disagrees with index: kind/n/h ({kind:?},{n},{h}) vs ({:?},{},{})",
            index.kind,
            index.n,
            index.h
        );
        ensure!(
            off_b == index.section_b_offset,
            "section B offset mismatch: header {off_b}, index {}",
            index.section_b_offset
        );
        if kind == Kind::Nest {
            ensure!(h >= 1 && h < n && n <= 16, "bad nest header n={n} h={h}");
        }

        let mut tensors = Vec::with_capacity(num);
        for _ in 0..num {
            let tname = c.str()?;
            let ptype = c.u8()?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let count: usize = shape.iter().product();
            let payload = match (ptype, kind) {
                (1, _) => {
                    let start = c.o;
                    c.raw(4 * count)?;
                    Payload::Fp32 { values: start..c.o }
                }
                (0, Kind::Nest) | (0, Kind::Mono) => {
                    let ns = c.u32()? as usize;
                    let sstart = c.o;
                    c.raw(4 * ns)?;
                    let scales = sstart..c.o;
                    let pstart = c.o;
                    let bits = c.u8()?;
                    ensure!(
                        (bits::MIN_BITS..=bits::MAX_BITS).contains(&bits),
                        "{tname}: packed bits {bits} out of range"
                    );
                    let expect = if kind == Kind::Nest { h } else { n };
                    ensure!(bits == expect, "{tname}: packed bits {bits} != header {expect}");
                    let nw = c.u32()? as usize;
                    ensure!(
                        nw == packed_nwords(count, bits),
                        "{tname}: INT{bits} x {count} needs {} words, got {nw}",
                        packed_nwords(count, bits)
                    );
                    c.raw(8 * nw)?;
                    Payload::Quant {
                        scales,
                        packed: PackedRange {
                            bits,
                            count,
                            start: pstart,
                            len: c.o - pstart,
                        },
                        low: None,
                    }
                }
                (0, Kind::Fp32) => bail!("fp32 container cannot hold quantized tensors"),
                (p, _) => bail!("unknown ptype {p}"),
            };
            tensors.push(TensorLayout {
                name: tname,
                shape,
                count,
                payload,
            });
        }
        ensure!(c.o == a.len(), "trailing bytes in section A");
        if kind == Kind::Nest {
            ensure!(
                off_b as usize == c.o,
                "section B offset mismatch: {} vs {}",
                off_b,
                c.o
            );
        }

        // Section-B layout follows from shape arithmetic alone — one
        // `l+1`-bit block per quantized tensor in section-A order.
        let mut b_len = 0usize;
        if kind == Kind::Nest {
            let low_bits = n - h + 1;
            for t in &mut tensors {
                if let Payload::Quant { low, .. } = &mut t.payload {
                    let nw = packed_nwords(t.count, low_bits);
                    let len = 5 + 8 * nw;
                    *low = Some(PackedRange {
                        bits: low_bits,
                        count: t.count,
                        start: b_len,
                        len,
                    });
                    b_len += len;
                }
            }
        }
        // An A-only source (a section-A blob wrapped as a whole
        // artifact: off_b == file_len) is a legal part-bit-only archive;
        // `full_bit()` fails cleanly at verify. Otherwise the computed
        // geometry must match the source exactly.
        if index.section_b_bytes() > 0 {
            ensure!(
                b_len as u64 == index.section_b_bytes(),
                "computed section B length {b_len} != index {}",
                index.section_b_bytes()
            );
        }

        Ok(ModelLayout {
            kind,
            n,
            h,
            act_bits,
            name,
            meta,
            section_b_offset: off_b,
            a_len: a.len(),
            b_len,
            tensors,
        })
    }

    /// Check fetched section-B bytes against the computed layout (block
    /// headers + total length). Cheap: 5 bytes per quantized tensor.
    pub(crate) fn verify_b(&self, b: &[u8]) -> Result<()> {
        ensure!(self.kind == Kind::Nest, "section B only exists for nest containers");
        ensure!(
            b.len() == self.b_len,
            "section B is {} bytes, layout says {}",
            b.len(),
            self.b_len
        );
        for t in &self.tensors {
            if let Payload::Quant { low: Some(l), .. } = &t.payload {
                let bits = b[l.start];
                ensure!(bits == l.bits, "{}: w_low bits {bits} != l+1 {}", t.name, l.bits);
                let nw =
                    u32::from_le_bytes(b[l.start + 1..l.start + 5].try_into().unwrap()) as usize;
                ensure!(
                    5 + 8 * nw == l.len,
                    "{}: w_low block {} words != computed {}",
                    t.name,
                    nw,
                    (l.len - 5) / 8
                );
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// borrowed views
// ---------------------------------------------------------------------------

/// Borrowed little-endian f32 array (alignment-free: the `.nq` layout
/// interleaves strings, so payloads are not 4-aligned in general).
#[derive(Debug, Clone, Copy)]
pub struct F32View<'m> {
    bytes: &'m [u8],
}

impl<'m> F32View<'m> {
    pub fn len(&self) -> usize {
        self.bytes.len() / 4
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn get(&self, i: usize) -> f32 {
        f32::from_le_bytes(self.bytes[4 * i..4 * i + 4].try_into().unwrap())
    }

    pub fn iter(&self) -> impl Iterator<Item = f32> + 'm {
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
    }

    /// Decode into a caller buffer (hot path: reused across switches).
    pub fn read_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len());
        out.extend(self.iter());
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.iter().collect()
    }
}

/// Borrowed packed k-bit tensor: decodes words straight from section
/// bytes (cf. [`PackedTensor`], which owns its words).
#[derive(Debug, Clone, Copy)]
pub struct PackedView<'m> {
    bytes: &'m [u8],
    bits: u8,
    count: usize,
}

impl<'m> PackedView<'m> {
    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// On-disk payload bytes (words only).
    pub fn nbytes(&self) -> usize {
        self.bytes.len()
    }

    fn word(&self, i: usize) -> u64 {
        u64::from_le_bytes(self.bytes[8 * i..8 * i + 8].try_into().unwrap())
    }

    fn words_iter(&self) -> impl Iterator<Item = u64> + 'm {
        self.bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
    }

    /// Element at `i`, sign-extended.
    pub fn get(&self, i: usize) -> i32 {
        debug_assert!(i < self.count);
        let n_lanes = bits::lanes(self.bits);
        let shift = (i % n_lanes) * self.bits as usize;
        let field = (self.word(i / n_lanes) >> shift) & ((1u64 << self.bits) - 1);
        bits::sign_extend(field, self.bits)
    }

    /// Unpack into a caller buffer (i32 intermediate — compat and the
    /// non-dequantizing consumers; the switch path uses the fused
    /// kernels below). Dispatches straight from the section bytes into
    /// the process-selected kernel tier (`crate::kernels`).
    pub fn unpack_into(&self, out: &mut Vec<i32>) {
        crate::kernels::unpack_ints_into(self.bytes, self.bits, self.count, out);
    }

    /// Fused one-pass decode straight from the section bytes:
    /// `out[i] = value · scales[i % c] · scale_mul` — the part-bit
    /// launch kernel (`scale_mul = 2^l`, Eq. 10) and the mono decode
    /// (`scale_mul = 1`). See [`crate::kernels::unpack_dequant_into`].
    pub fn unpack_dequant_into(&self, scales: &[f32], scale_mul: f32, out: &mut Vec<f32>) {
        crate::kernels::unpack_dequant_into(
            self.bytes, self.bits, self.count, scales, scale_mul, out,
        );
    }

    /// Fused full-bit upgrade decode: `self` as the packed `w_high`
    /// stream plus `low` as the packed `w_low` stream →
    /// `out[i] = s · (w_high·2^l + w_low)` in one pass with no i32
    /// materialization. See [`crate::kernels::recompose_dequant_into`].
    pub fn recompose_dequant_into(
        &self,
        low: &PackedView<'_>,
        l: u8,
        scales: &[f32],
        out: &mut Vec<f32>,
    ) {
        assert_eq!(
            self.count, low.count,
            "recompose_dequant_into: w_high has {} values, w_low {}",
            self.count, low.count
        );
        crate::kernels::recompose_dequant_into(
            self.bytes, self.bits, low.bytes, low.bits, l, self.count, scales, out,
        );
    }

    /// Integer-domain GEMV straight from the section bytes:
    /// `acc[c] = Σ_r x[r] · w[r·classes + c]` with the packed stream as
    /// the row-major weight matrix — no decode pass, no f32, no i32
    /// weight vector. The caller folds `s_x · s_w` (and the part-bit
    /// `2^l`) into a per-class rescale of the accumulators; see
    /// [`crate::kernels::gemm_i32_into`].
    pub fn gemm_i32_into(&self, x: &[i32], classes: usize, acc: &mut Vec<i32>) {
        assert_eq!(
            x.len() * classes,
            self.count,
            "gemm_i32_into: {} rows x {classes} classes != {} packed values",
            x.len(),
            self.count
        );
        crate::kernels::gemm_i32_into(self.bytes, self.bits, x, classes, acc);
    }

    pub fn unpack(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.count);
        self.unpack_into(&mut out);
        out
    }

    /// Materialize an owned [`PackedTensor`] (compat / tests — copies).
    pub fn to_packed(&self) -> Result<PackedTensor> {
        PackedTensor::from_words(self.words_iter().collect(), self.bits, self.count)
    }
}

/// One tensor's payload through the typed views.
#[derive(Debug, Clone, Copy)]
pub enum PayloadView<'m> {
    /// FP32 parameter (bias, layernorm, pos-emb).
    Fp32(F32View<'m>),
    /// NestQuant weight; `w_low` is `Some` iff viewed through a
    /// [`FullBitModel`].
    Nest {
        scales: F32View<'m>,
        w_high: PackedView<'m>,
        w_low: Option<PackedView<'m>>,
    },
    /// Monolithic packed weight.
    Mono {
        scales: F32View<'m>,
        w_int: PackedView<'m>,
    },
}

/// Borrowed view of one tensor inside a model view.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'m> {
    layout: &'m TensorLayout,
    kind: Kind,
    a: &'m [u8],
    b: Option<&'m [u8]>,
}

impl<'m> TensorView<'m> {
    pub fn name(&self) -> &'m str {
        &self.layout.name
    }

    pub fn shape(&self) -> &'m [usize] {
        &self.layout.shape
    }

    pub fn count(&self) -> usize {
        self.layout.count
    }

    pub fn layout(&self) -> &'m TensorLayout {
        self.layout
    }

    pub fn payload(&self) -> PayloadView<'m> {
        match &self.layout.payload {
            Payload::Fp32 { values } => PayloadView::Fp32(F32View {
                bytes: &self.a[values.clone()],
            }),
            Payload::Quant { scales, packed, low } => {
                let scales = F32View {
                    bytes: &self.a[scales.clone()],
                };
                let pv = PackedView {
                    bytes: &self.a[packed.words()],
                    bits: packed.bits,
                    count: packed.count,
                };
                match self.kind {
                    Kind::Nest => PayloadView::Nest {
                        scales,
                        w_high: pv,
                        w_low: match (low, self.b) {
                            (Some(l), Some(b)) => Some(PackedView {
                                bytes: &b[l.words()],
                                bits: l.bits,
                                count: l.count,
                            }),
                            _ => None,
                        },
                    },
                    Kind::Mono => PayloadView::Mono { scales, w_int: pv },
                    Kind::Fp32 => unreachable!("quant payload rejected for fp32 kind at parse"),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// typed model views
// ---------------------------------------------------------------------------

/// A model with only section A resident: the part-bit launch state
/// (for mono/fp32 containers, section A *is* the whole model). Holding
/// one is proof that `w_low` is not accessible — upgrading means asking
/// the archive for a [`FullBitModel`] instead.
pub struct PartBitModel {
    layout: Arc<ModelLayout>,
    a: Bytes,
}

impl PartBitModel {
    pub(crate) fn new(layout: Arc<ModelLayout>, a: Bytes) -> Result<PartBitModel> {
        ensure!(
            a.len() == layout.a_len,
            "section A is {} bytes, layout says {}",
            a.len(),
            layout.a_len
        );
        Ok(PartBitModel { layout, a })
    }

    pub fn layout(&self) -> &ModelLayout {
        &self.layout
    }

    pub fn len(&self) -> usize {
        self.layout.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layout.is_empty()
    }

    pub fn tensor(&self, i: usize) -> TensorView<'_> {
        TensorView {
            layout: &self.layout.tensors()[i],
            kind: self.layout.kind(),
            a: &self.a,
            b: None,
        }
    }

    pub fn tensors(&self) -> impl ExactSizeIterator<Item = TensorView<'_>> + '_ {
        (0..self.len()).map(move |i| self.tensor(i))
    }

    /// The resident section-A bytes (shared).
    pub fn section_a(&self) -> Bytes {
        self.a.clone()
    }
}

/// A model with both sections resident: the full-bit state. Dropping it
/// (plus `NqArchive::release_b`) *is* the downgrade — section A and the
/// layout stay untouched.
pub struct FullBitModel {
    layout: Arc<ModelLayout>,
    a: Bytes,
    b: Bytes,
}

impl FullBitModel {
    pub(crate) fn new(layout: Arc<ModelLayout>, a: Bytes, b: Bytes) -> Result<FullBitModel> {
        ensure!(
            a.len() == layout.a_len,
            "section A is {} bytes, layout says {}",
            a.len(),
            layout.a_len
        );
        layout.verify_b(&b)?;
        Ok(FullBitModel { layout, a, b })
    }

    pub fn layout(&self) -> &ModelLayout {
        &self.layout
    }

    pub fn len(&self) -> usize {
        self.layout.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layout.is_empty()
    }

    pub fn tensor(&self, i: usize) -> TensorView<'_> {
        TensorView {
            layout: &self.layout.tensors()[i],
            kind: self.layout.kind(),
            a: &self.a,
            b: Some(&self.b),
        }
    }

    pub fn tensors(&self) -> impl ExactSizeIterator<Item = TensorView<'_>> + '_ {
        (0..self.len()).map(move |i| self.tensor(i))
    }

    /// The resident section-A bytes (shared).
    pub fn section_a(&self) -> Bytes {
        self.a.clone()
    }

    /// The resident section-B bytes (shared).
    pub fn section_b(&self) -> Bytes {
        self.b.clone()
    }
}
