//! [`MmapSource`]: a `.nq` artifact on disk served through `mmap(2)`,
//! so section fetches hand out OS-paged windows instead of heap copies.
//!
//! The zoo-scale story: a [`super::FileSource`] fetch reads the whole
//! section into owned memory, so a 1000-model zoo pays full RAM for
//! every resident section. Mapping the artifact instead makes a fetch a
//! pointer-window over the file — the kernel pages bytes in on first
//! touch (`madvise(MADV_SEQUENTIAL)` hints the sequential decode) and
//! drops them on memory pressure or an explicit
//! `madvise(MADV_DONTNEED)` at release. Residency ledgers must treat
//! such bytes as *not theirs to free* — hence [`super::Bytes::is_mapped`]
//! and the separate `nq_store_mapped_bytes` gauge.
//!
//! Portability and failure policy: the mapping path exists on unix with
//! the `mmap` cargo feature (default); elsewhere — and whenever the map
//! attempt fails (failpoint `store.map`, exotic filesystems, fd
//! pressure) — the source degrades *gracefully* to positioned reads,
//! byte-identical to `FileSource`, with a `map_fault` trace event and a
//! `nq_store_map_faults` counter bump instead of an error. The degrade
//! verdict is memoized: one attempt per source, never one per fetch.
//!
//! The syscall bindings are hand-declared (same idiom as
//! `reactor::sys`): the workspace links no libc crate.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;
#[cfg(all(unix, feature = "mmap"))]
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::container::{self, SectionIndex};

use super::{Bytes, Section, SectionSource};

#[cfg(all(unix, feature = "mmap"))]
mod sys {
    //! Minimal raw `mmap`/`munmap`/`madvise` declarations (linux/macOS
    //! share these constant values for the subset used here).
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x2;
    pub const MADV_SEQUENTIAL: c_int = 2;
    pub const MADV_DONTNEED: c_int = 4;

    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// One live read-only mapping of a whole artifact. Shared by every
/// [`Bytes`] window cut from it; unmapped when the last window drops.
/// The `nq_store_mapped_bytes` gauge tracks the mapping's lifetime.
#[cfg(all(unix, feature = "mmap"))]
pub(crate) struct MapRegion {
    ptr: *mut u8,
    len: usize,
}

// Safety: the mapping is PROT_READ-only and owned exclusively by this
// struct until Drop; aliasing shared `&[u8]` views across threads over
// immutable pages is sound.
#[cfg(all(unix, feature = "mmap"))]
unsafe impl Send for MapRegion {}
#[cfg(all(unix, feature = "mmap"))]
unsafe impl Sync for MapRegion {}

#[cfg(all(unix, feature = "mmap"))]
impl MapRegion {
    fn map(file: &std::fs::File, len: usize) -> std::io::Result<MapRegion> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            return Err(std::io::Error::last_os_error());
        }
        crate::telemetry::registry().store.mapped_bytes.add(len as u64);
        Ok(MapRegion {
            ptr: ptr as *mut u8,
            len,
        })
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// `madvise` over a window, start aligned down to the page (the
    /// kernel rejects unaligned addresses). Advisory: errors ignored —
    /// a refused hint changes behavior, never correctness.
    fn advise(&self, offset: usize, len: usize, advice: i32) {
        const PAGE: usize = 4096;
        let start = offset & !(PAGE - 1);
        let end = (offset + len).min(self.len);
        if end <= start {
            return;
        }
        let _ = unsafe { sys::madvise(self.ptr.add(start).cast(), end - start, advice) };
    }

    pub(crate) fn advise_sequential(&self, offset: usize, len: usize) {
        self.advise(offset, len, sys::MADV_SEQUENTIAL);
    }

    pub(crate) fn advise_dontneed(&self, offset: usize, len: usize) {
        self.advise(offset, len, sys::MADV_DONTNEED);
    }
}

#[cfg(all(unix, feature = "mmap"))]
impl Drop for MapRegion {
    fn drop(&mut self) {
        crate::telemetry::registry().store.mapped_bytes.sub(self.len as u64);
        unsafe {
            sys::munmap(self.ptr.cast(), self.len);
        }
    }
}

#[cfg(all(unix, feature = "mmap"))]
impl std::fmt::Debug for MapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MapRegion({} B)", self.len)
    }
}

/// The memoized outcome of this source's single map attempt.
#[cfg(all(unix, feature = "mmap"))]
enum MapState {
    Untried,
    Ready(Arc<MapRegion>),
    /// Mapping failed once — every fetch uses positioned reads from now
    /// on (one fault counted, not one per fetch).
    Degraded,
}

/// A `.nq` artifact on disk, sections served as `mmap(2)` windows with
/// graceful degrade to positioned reads (see the module docs). Drop-in
/// for [`super::FileSource`]: same memoized header probe, byte-identical
/// fetches, same `describe()` (the path).
pub struct MmapSource {
    path: PathBuf,
    index: OnceLock<SectionIndex>,
    #[cfg(all(unix, feature = "mmap"))]
    map: Mutex<MapState>,
}

impl MmapSource {
    pub fn new(path: impl Into<PathBuf>) -> MmapSource {
        MmapSource {
            path: path.into(),
            index: OnceLock::new(),
            #[cfg(all(unix, feature = "mmap"))]
            map: Mutex::new(MapState::Untried),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Map the whole artifact (first fetch only). Failpoint `store.map`
    /// forges a failure down the same degrade path a real ENOMEM takes.
    #[cfg(all(unix, feature = "mmap"))]
    fn try_map(&self) -> Result<Arc<MapRegion>> {
        crate::faults::fail_point("store.map")?;
        let file = std::fs::File::open(&self.path)?;
        let len = file.metadata()?.len();
        anyhow::ensure!(len > 0, "empty artifact cannot be mapped");
        Ok(Arc::new(MapRegion::map(&file, len as usize)?))
    }

    /// A mapped window for `range`, or `None` when this source runs (or
    /// now degrades to) positioned reads.
    #[cfg(all(unix, feature = "mmap"))]
    fn window(&self, range: &std::ops::Range<u64>) -> Option<Bytes> {
        let mut g = self.map.lock().unwrap_or_else(|e| e.into_inner());
        if matches!(*g, MapState::Untried) {
            *g = match self.try_map() {
                Ok(region) => MapState::Ready(region),
                Err(e) => {
                    crate::telemetry::registry().store.map_faults.inc();
                    crate::nq_trace!(
                        crate::telemetry::TraceKind::MapFault,
                        "mmap of {} failed ({e:#}); degrading to positioned reads",
                        self.path.display()
                    );
                    MapState::Degraded
                }
            };
        }
        match &*g {
            MapState::Ready(region) if range.end as usize <= region.len() => Some(Bytes::mapped(
                Arc::clone(region),
                range.start as usize,
                (range.end - range.start) as usize,
            )),
            _ => None,
        }
    }
}

impl SectionSource for MmapSource {
    fn index(&self) -> Result<SectionIndex> {
        if let Some(i) = self.index.get() {
            return Ok(i.clone());
        }
        let idx = container::probe_impl(&self.path)?;
        // a racer may have probed concurrently; first insert wins
        Ok(self.index.get_or_init(|| idx).clone())
    }

    fn fetch(&self, section: Section) -> Result<Bytes> {
        let idx = SectionSource::index(self)?;
        let range = match section {
            Section::A => idx.section_a(),
            Section::B => idx.section_b(),
        };
        // empty sections (A-only artifacts) never justify a mapping
        #[cfg(all(unix, feature = "mmap"))]
        if range.start < range.end {
            if let Some(bytes) = self.window(&range) {
                bytes.advise_sequential();
                return Ok(bytes);
            }
        }
        Ok(container::read_range_impl(&self.path, range)?.into())
    }

    fn describe(&self) -> String {
        self.path.display().to_string()
    }
}

impl std::fmt::Debug for MmapSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapSource").field("path", &self.path).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::synthetic_nest;

    fn temp_nq(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nq_mmap_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let c = synthetic_nest(11, 8, 4, 48, 8).unwrap();
        let path = dir.join("m.nq");
        container::write(&path, &c).unwrap();
        path
    }

    #[test]
    fn mmap_source_matches_file_source() {
        let path = temp_nq("ident");
        let ms = MmapSource::new(&path);
        let fs = super::super::FileSource::new(&path);
        assert_eq!(ms.index().unwrap(), fs.index().unwrap());
        for s in [Section::A, Section::B] {
            let mb = ms.fetch(s).unwrap();
            let fb = fs.fetch(s).unwrap();
            assert_eq!(&mb[..], &fb[..], "section {s}");
            #[cfg(all(unix, feature = "mmap"))]
            assert!(mb.is_mapped(), "section {s} should be a mapped window");
            assert!(!fb.is_mapped());
        }
        assert_eq!(ms.describe(), fs.describe());
    }

    #[cfg(all(unix, feature = "mmap"))]
    #[test]
    fn windows_share_one_region_and_advise_is_harmless() {
        let path = temp_nq("share");
        let ms = MmapSource::new(&path);
        let a1 = ms.fetch(Section::A).unwrap();
        let a2 = ms.fetch(Section::A).unwrap();
        assert!(a1.ptr_eq(&a2), "one mapping, windows are pointer-equal");
        a1.advise_sequential();
        a1.advise_dontneed();
        // bytes remain readable after DONTNEED (file-backed: refault)
        assert_eq!(&a1[..], &a2[..]);
    }

    #[test]
    fn missing_file_is_a_probe_error_not_a_panic() {
        let ms = MmapSource::new("/nonexistent/not_there.nq");
        assert!(ms.index().is_err());
        assert!(ms.fetch(Section::A).is_err());
    }
}
