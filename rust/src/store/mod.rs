//! ModelStore (S11): the one access layer over `.nq` artifacts.
//!
//! The paper's switching economy — part-bit vs full-bit as literal byte
//! ranges of one artifact (Table 11, Figs 13/14) — deserves an API where
//! that economy is visible in the types:
//!
//! * [`SectionSource`] — *where bytes come from*: a local file
//!   ([`FileSource`], positioned reads, memoized header probe; or
//!   [`MmapSource`], the same artifact OS-paged through `mmap(2)`), an
//!   in-memory blob ([`MemorySource`], synthetic zoos and transport
//!   hand-offs), or a fleet server (`fleet::RemoteSource`).
//! * [`NqArchive`] — *one open artifact*: fetch section A once into a
//!   shared [`Bytes`] handle, parse the tensor layout once, and hand
//!   out borrowed views. Section B attaches as a second handle and
//!   detaches by dropping it — an upgrade is "attach a view", a
//!   downgrade is "drop a view"; no re-parse, no re-read of section A,
//!   ever.
//! * [`PartBitModel`] / [`FullBitModel`] — typed views whose existence
//!   proves which sections are resident; their [`TensorView`]s decode
//!   packed weights straight from the shared bytes (no intermediate
//!   word vectors).
//! * [`ModelStore`] — id → shared [`NqArchive`]; N consumers of the
//!   same artifact share one set of bytes through the archive's `Arc`s
//!   ([`ModelStore::global`] dedups by canonical path for read-mostly
//!   consumers like report tables and the diverse-bitwidths baseline;
//!   a `ModelManager` owns a private archive because its paging
//!   lifecycle releases sections).
//! * [`StoreBudget`] — one RAM cap on resident Section-B bytes *across*
//!   archives: attach through it and lower-bit sections of other
//!   tenants are LRU-evicted to fit (the multi-tenant server's shared
//!   budget; see `coordinator::server`).
//!
//! The old `container` free functions (`read`, `parse`, `probe`,
//! `read_range`, …) remain as `#[deprecated]` shims over the same
//! internals; `container` itself keeps the format (types, writer,
//! synthetic builder).
//!
//! Byte traffic is observable: [`NqArchive::stats`] counts section
//! fetches and layout parses, which is how `tests/store.rs` proves the
//! upgrade/downgrade path does zero section-A re-reads and zero
//! re-parses, and how `benches/switching.rs` reports bytes copied per
//! switch before vs after the view-based path.

mod archive;
mod budget;
mod layout;
mod mmap;

use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use crate::container::{self, SectionIndex};

pub use archive::{ArchiveStats, ModelStore, NqArchive};
pub use budget::{BudgetEvent, StoreBudget};
pub use layout::{
    F32View, FullBitModel, ModelLayout, PackedView, PartBitModel, PayloadView, TensorLayout,
    TensorView,
};
pub use mmap::MmapSource;

/// Shared immutable bytes (one section, or one whole artifact).
///
/// One cheap-to-clone handle over two representations: heap bytes in an
/// `Arc<[u8]>` (*owned* — the process pays RAM for them), or a window of
/// an `mmap(2)`-ed artifact (*mapped*, `mmap` feature on unix — the OS
/// pages them in and out; see [`MmapSource`]). Everything above the
/// source layer treats both the same through `Deref<Target = [u8]>`;
/// only residency accounting cares, via [`Bytes::is_mapped`]: a
/// [`StoreBudget`] eviction must never claim to "free" memory the OS
/// owns.
#[derive(Clone)]
pub struct Bytes(Repr);

#[derive(Clone)]
enum Repr {
    Owned(Arc<[u8]>),
    #[cfg(all(unix, feature = "mmap"))]
    Mapped {
        region: Arc<mmap::MapRegion>,
        offset: usize,
        len: usize,
    },
}

impl Bytes {
    fn as_slice(&self) -> &[u8] {
        match &self.0 {
            Repr::Owned(a) => a,
            #[cfg(all(unix, feature = "mmap"))]
            Repr::Mapped { region, offset, len } => &region.as_slice()[*offset..*offset + *len],
        }
    }

    /// Whether these bytes are OS-paged (a live mmap window) rather than
    /// owned heap memory. Mapped bytes are accounted separately in every
    /// residency ledger ([`ArchiveStats`], [`StoreBudget`], the
    /// `nq_store_mapped_bytes` gauge).
    pub fn is_mapped(&self) -> bool {
        match &self.0 {
            Repr::Owned(_) => false,
            #[cfg(all(unix, feature = "mmap"))]
            Repr::Mapped { .. } => true,
        }
    }

    /// Pointer identity: do two handles view the exact same memory?
    /// (The newtype's replacement for `Arc::ptr_eq` on the old alias.)
    pub fn ptr_eq(&self, other: &Bytes) -> bool {
        let (a, b) = (self.as_slice(), other.as_slice());
        std::ptr::eq(a.as_ptr(), b.as_ptr()) && a.len() == b.len()
    }

    /// Wrap a window of a mapped region (the [`MmapSource`] fetch path).
    #[cfg(all(unix, feature = "mmap"))]
    pub(crate) fn mapped(region: Arc<mmap::MapRegion>, offset: usize, len: usize) -> Bytes {
        debug_assert!(offset + len <= region.len());
        Bytes(Repr::Mapped { region, offset, len })
    }

    /// `madvise(MADV_SEQUENTIAL)` over a mapped window — the read-ahead
    /// hint before a front-to-back decode. No-op for owned bytes;
    /// advisory, so refusals are ignored.
    pub fn advise_sequential(&self) {
        match &self.0 {
            Repr::Owned(_) => {}
            #[cfg(all(unix, feature = "mmap"))]
            Repr::Mapped { region, offset, len } => region.advise_sequential(*offset, *len),
        }
    }

    /// `madvise(MADV_DONTNEED)` over a mapped window — tells the OS the
    /// pages can go (the mmap analogue of dropping owned section bytes
    /// on `release_b`). No-op for owned bytes; advisory.
    pub fn advise_dontneed(&self) {
        match &self.0 {
            Repr::Owned(_) => {}
            #[cfg(all(unix, feature = "mmap"))]
            Repr::Mapped { region, offset, len } => region.advise_dontneed(*offset, *len),
        }
    }
}

impl std::ops::Deref for Bytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes(Repr::Owned(v.into()))
    }
}

impl From<&[u8]> for Bytes {
    fn from(v: &[u8]) -> Bytes {
        Bytes(Repr::Owned(v.into()))
    }
}

impl From<Arc<[u8]>> for Bytes {
    fn from(a: Arc<[u8]>) -> Bytes {
        Bytes(Repr::Owned(a))
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let tag = if self.is_mapped() { "mapped" } else { "owned" };
        write!(f, "Bytes({} B, {tag})", self.as_slice().len())
    }
}

/// Which `.nq` section a byte range or transfer refers to.
///
/// (Re-exported as `fleet::Section`; the wire tags are part of the fleet
/// protocol.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Section {
    /// Header + scales + packed `w_high` + fp32 params (part-bit launch).
    A,
    /// Packed `w_low` tail (the upgrade delta).
    B,
}

impl Section {
    pub fn tag(self) -> u8 {
        match self {
            Section::A => 0,
            Section::B => 1,
        }
    }

    pub fn from_tag(t: u8) -> Result<Section> {
        Ok(match t {
            0 => Section::A,
            1 => Section::B,
            _ => bail!("unknown section tag {t}"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Section::A => "A",
            Section::B => "B",
        }
    }
}

impl std::fmt::Display for Section {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Where an archive's bytes come from. One implementation per tier:
/// [`FileSource`] (disk), [`MemorySource`] (RAM), `fleet::RemoteSource`
/// (another machine). Everything above — [`NqArchive`], the fleet
/// `SectionCache`, the coordinator — is source-agnostic.
pub trait SectionSource: Send + Sync {
    /// Section layout. Implementations touch as little as possible (a
    /// header probe, a memoized copy, one wire round-trip) and memoize.
    fn index(&self) -> Result<SectionIndex>;

    /// Fetch one section's bytes. This is the *only* way bytes move out
    /// of a source, so fetch counts are the paging ground truth.
    fn fetch(&self, section: Section) -> Result<Bytes>;

    /// Human-readable origin for diagnostics ("path", "memory:name",
    /// "fleet:addr/model").
    fn describe(&self) -> String;
}

/// Raw positioned byte-range read from any file (pread-style; never
/// moves a shared cursor). The blessed replacement for the deprecated
/// `container::read_range`.
pub fn read_file_range(path: &Path, range: std::ops::Range<u64>) -> Result<Vec<u8>> {
    container::read_range_impl(path, range)
}

// ---------------------------------------------------------------------------
// FileSource
// ---------------------------------------------------------------------------

/// A `.nq` artifact on disk. The header probe runs once (memoized);
/// section fetches are positioned reads, so concurrent fetches on one
/// source never race on a file cursor.
#[derive(Debug)]
pub struct FileSource {
    path: PathBuf,
    index: OnceLock<SectionIndex>,
}

impl FileSource {
    pub fn new(path: impl Into<PathBuf>) -> FileSource {
        FileSource {
            path: path.into(),
            index: OnceLock::new(),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl SectionSource for FileSource {
    fn index(&self) -> Result<SectionIndex> {
        if let Some(i) = self.index.get() {
            return Ok(i.clone());
        }
        let idx = container::probe_impl(&self.path)?;
        // a racer may have probed concurrently; first insert wins
        Ok(self.index.get_or_init(|| idx).clone())
    }

    fn fetch(&self, section: Section) -> Result<Bytes> {
        let idx = SectionSource::index(self)?;
        let range = match section {
            Section::A => idx.section_a(),
            Section::B => idx.section_b(),
        };
        Ok(container::read_range_impl(&self.path, range)?.into())
    }

    fn describe(&self) -> String {
        self.path.display().to_string()
    }
}

// ---------------------------------------------------------------------------
// MemorySource
// ---------------------------------------------------------------------------

/// A whole `.nq` artifact already in memory: synthetic containers,
/// transport hand-offs, tests. Sections are split once at construction;
/// fetches are `Arc` clones.
pub struct MemorySource {
    index: SectionIndex,
    a: Bytes,
    b: Bytes,
}

impl MemorySource {
    /// Wrap serialized container bytes (validates the header). Sections
    /// are sliced by the index ranges, so an integrity trailer at the
    /// end of the blob stays out of both sections.
    pub fn new(data: &[u8]) -> Result<MemorySource> {
        let index = container::index_of_bytes(data).context("indexing in-memory container")?;
        let (ra, rb) = (index.section_a(), index.section_b());
        ensure!(rb.end as usize <= data.len(), "section B end beyond data");
        Ok(MemorySource {
            a: data[ra.start as usize..ra.end as usize].into(),
            b: data[rb.start as usize..rb.end as usize].into(),
            index,
        })
    }

    /// Serialize a [`container::Container`] and wrap it (the synthetic
    /// zoo path).
    pub fn from_container(c: &container::Container) -> Result<MemorySource> {
        MemorySource::new(&container::serialize(c)?)
    }
}

impl SectionSource for MemorySource {
    fn index(&self) -> Result<SectionIndex> {
        Ok(self.index.clone())
    }

    fn fetch(&self, section: Section) -> Result<Bytes> {
        Ok(match section {
            Section::A => self.a.clone(),
            Section::B => self.b.clone(),
        })
    }

    fn describe(&self) -> String {
        format!("memory:{}", self.index.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{synthetic_nest, Kind};

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nq_store_src_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn section_tag_roundtrip() {
        for s in [Section::A, Section::B] {
            assert_eq!(Section::from_tag(s.tag()).unwrap(), s);
        }
        assert!(Section::from_tag(7).is_err());
        assert_eq!(Section::A.to_string(), "A");
        assert_eq!(Section::B.label(), "B");
    }

    #[test]
    fn file_and_memory_sources_agree() {
        let dir = temp_dir("agree");
        let c = synthetic_nest(3, 8, 4, 48, 8).unwrap();
        let bytes = container::serialize(&c).unwrap();
        let path = dir.join("m.nq");
        std::fs::write(&path, &bytes).unwrap();

        let fs = FileSource::new(&path);
        let ms = MemorySource::new(&bytes).unwrap();
        let fi = fs.index().unwrap();
        let mi = ms.index().unwrap();
        assert_eq!(fi, mi);
        assert_eq!(fi.kind, Kind::Nest);
        for s in [Section::A, Section::B] {
            let fb = fs.fetch(s).unwrap();
            let mb = ms.fetch(s).unwrap();
            assert_eq!(&fb[..], &mb[..], "section {s}");
        }
        // A ++ B == the serialized payload (the trailer rides after it)
        let mut whole = fs.fetch(Section::A).unwrap().to_vec();
        whole.extend_from_slice(&fs.fetch(Section::B).unwrap());
        assert_eq!(whole[..], bytes[..fi.payload_len() as usize]);
        assert_eq!(fi.payload_len() + fi.trailer_len(), bytes.len() as u64);
        assert!(fs.describe().contains("m.nq"));
        assert!(ms.describe().starts_with("memory:"));
    }

    #[test]
    fn memory_source_rejects_garbage() {
        assert!(MemorySource::new(b"not a container").is_err());
    }

    #[test]
    fn read_file_range_is_positioned() {
        let dir = temp_dir("range");
        let path = dir.join("blob.bin");
        let data: Vec<u8> = (0..=255u8).collect();
        std::fs::write(&path, &data).unwrap();
        assert_eq!(read_file_range(&path, 10..20).unwrap(), &data[10..20]);
        assert_eq!(read_file_range(&path, 0..0).unwrap(), Vec::<u8>::new());
        assert!(read_file_range(&path, 250..300).is_err());
        #[allow(clippy::reversed_empty_ranges)]
        {
            assert!(read_file_range(&path, 20..10).is_err());
        }
    }
}
