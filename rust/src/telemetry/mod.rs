//! Fleet-wide telemetry (S13): one process-global, lock-light registry of
//! counters, gauges, and latency histograms across the store, kernels,
//! fleet, and serving subsystems — plus a bounded ring-buffer event trace.
//!
//! Design contract:
//!
//! - **Lock-light recording.** Every counter/gauge record is exactly one
//!   relaxed `fetch_add`; the kernel decode hot path records one call and
//!   one byte count — two relaxed atomics total, nothing else. Histogram
//!   records are four relaxed atomics and only appear on per-request /
//!   per-switch paths, never inside decode loops.
//! - **Const-constructed global.** The registry is a `static` built by
//!   `const fn`s, so [`registry()`] is a plain reference — no `OnceLock`
//!   acquire-load on the hot path and no lazy-init branch.
//! - **Zero-cost-when-disabled tracing.** The [`TraceRing`] is gated by
//!   one `AtomicBool`; the [`nq_trace!`] macro checks the gate *before*
//!   evaluating its format arguments, so a disabled ring costs a single
//!   relaxed load — no formatting, no allocation, no lock.
//!
//! Scrape surfaces (see [`Snapshot`]): the `metrics` wire command on both
//! TCP servers (versioned JSON), `nestquant metrics --prom` (Prometheus
//! text exposition), and `nestquant top` (human table). All three render
//! from the same gathered snapshot, so totals are identical by
//! construction.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

mod snapshot;
pub use snapshot::{validate_prometheus, HistoSnapshot, Snapshot, TenantSnapshot};

// ---------------------------------------------------------------------------
// primitives
// ---------------------------------------------------------------------------

/// Monotonic counter: one relaxed `fetch_add` per record.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// Instantaneous level (resident bytes, queue depth). Call sites pair
/// every `sub` with an earlier `add` of the same amount, so the value
/// never underflows.
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.sub(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Log2-bucketed latency histogram from 1µs to ~17min (promoted here
/// from `coordinator/metrics.rs`; that module is now a thin shim).
#[derive(Debug)]
pub struct LatencyHisto {
    /// bucket i covers [2^i, 2^{i+1}) microseconds.
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHisto {
    pub const fn new() -> LatencyHisto {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        LatencyHisto {
            buckets: [ZERO; 32],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto::new()
    }
}

// ---------------------------------------------------------------------------
// per-tenant metrics (promoted from coordinator/metrics.rs)
// ---------------------------------------------------------------------------

/// Coordinator-wide metrics: one instance per tenant/coordinator, owned
/// by the serving layer (NOT process-global, so parallel tests and
/// tenants never cross-contaminate). The global [`Registry`] aggregates
/// across tenants; a wire snapshot carries both.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_occupancy_sum: AtomicU64,
    pub upgrades: AtomicU64,
    pub downgrades: AtomicU64,
    pub page_in_bytes: AtomicU64,
    pub page_out_bytes: AtomicU64,
    pub errors: AtomicU64,
    /// Circuit-breaker state for this tenant: 0 closed, 1 open,
    /// 2 half-open (see `faults::BreakerState::code`).
    pub breaker_state: AtomicU64,
    pub request_latency: LatencyHisto,
    pub execute_latency: LatencyHisto,
    pub switch_latency: LatencyHisto,
}

impl Metrics {
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Render a human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} occupancy={:.2} upgrades={} downgrades={} \
             page_in={}B page_out={}B errors={}\n\
             latency: exec mean={:.0}us p50={}us p99={}us max={}us | \
             request mean={:.0}us p99={}us | switch mean={:.0}us max={}us",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.upgrades.load(Ordering::Relaxed),
            self.downgrades.load(Ordering::Relaxed),
            self.page_in_bytes.load(Ordering::Relaxed),
            self.page_out_bytes.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.execute_latency.mean_us(),
            self.execute_latency.quantile_us(0.5),
            self.execute_latency.quantile_us(0.99),
            self.execute_latency.max_us(),
            self.request_latency.mean_us(),
            self.request_latency.quantile_us(0.99),
            self.switch_latency.mean_us(),
            self.switch_latency.max_us(),
        )
    }
}

// ---------------------------------------------------------------------------
// subsystem groups
// ---------------------------------------------------------------------------

/// Store (S11) counters: archive lifecycle, section traffic, integrity,
/// and the shared Section-B budget.
#[derive(Debug)]
pub struct StoreTelemetry {
    pub archive_opens: Counter,
    pub crc_failures: Counter,
    pub a_fetches: Counter,
    pub b_fetches: Counter,
    pub a_bytes_fetched: Counter,
    pub b_bytes_fetched: Counter,
    pub b_releases: Counter,
    /// `StoreBudget` cross-tenant evictions.
    pub evictions: Counter,
    pub evicted_bytes: Counter,
    /// Section-A bytes currently resident across all archives.
    pub resident_a_bytes: Gauge,
    /// Section-B bytes currently resident across all archives.
    pub resident_b_bytes: Gauge,
    /// Bytes currently under live `mmap` regions (OS-paged, not owned —
    /// disjoint from the resident gauges, which count heap bytes only).
    pub mapped_bytes: Gauge,
    /// `MmapSource` map attempts that failed and degraded to positioned
    /// reads (failpoint `store.map` fires down the same path).
    pub map_faults: Counter,
}

impl StoreTelemetry {
    pub const fn new() -> StoreTelemetry {
        StoreTelemetry {
            archive_opens: Counter::new(),
            crc_failures: Counter::new(),
            a_fetches: Counter::new(),
            b_fetches: Counter::new(),
            a_bytes_fetched: Counter::new(),
            b_bytes_fetched: Counter::new(),
            b_releases: Counter::new(),
            evictions: Counter::new(),
            evicted_bytes: Counter::new(),
            resident_a_bytes: Gauge::new(),
            resident_b_bytes: Gauge::new(),
            mapped_bytes: Gauge::new(),
            map_faults: Counter::new(),
        }
    }
}

impl Default for StoreTelemetry {
    fn default() -> Self {
        StoreTelemetry::new()
    }
}

/// Canonical kernel op names, indexed by the `OP_*` constants.
pub const KERNEL_OPS: [&str; 4] =
    ["unpack_dequant", "recompose_dequant", "unpack_ints", "gemm_i32"];
/// Canonical dispatch-tier names, indexed by `kernels::Tier as usize`.
pub const KERNEL_TIERS: [&str; 3] = ["scalar", "swar", "simd"];

pub const OP_UNPACK_DEQUANT: usize = 0;
pub const OP_RECOMPOSE_DEQUANT: usize = 1;
pub const OP_UNPACK_INTS: usize = 2;
pub const OP_GEMM_I32: usize = 3;

/// Kernel (S12) counters: decoded output bytes and call counts per
/// (op, dispatch tier), so the SWAR-vs-SIMD share is visible live.
#[derive(Debug)]
pub struct KernelTelemetry {
    /// `calls[op][tier]`
    calls: [[Counter; 3]; 4],
    /// `bytes[op][tier]` — decoded *output* bytes (f32 lanes × 4; for
    /// `gemm_i32`, processed packed fields × 4 — the i32s the matmul
    /// consumed without ever materializing them).
    bytes: [[Counter; 3]; 4],
}

impl KernelTelemetry {
    pub const fn new() -> KernelTelemetry {
        #[allow(clippy::declare_interior_mutable_const)]
        const C: Counter = Counter::new();
        #[allow(clippy::declare_interior_mutable_const)]
        const ROW: [Counter; 3] = [C, C, C];
        KernelTelemetry {
            calls: [ROW, ROW, ROW, ROW],
            bytes: [ROW, ROW, ROW, ROW],
        }
    }

    /// The decode hot-path record: exactly two relaxed atomic adds.
    #[inline]
    pub fn record(&self, op: usize, tier: usize, out_bytes: u64) {
        self.calls[op][tier].inc();
        self.bytes[op][tier].add(out_bytes);
    }

    pub fn calls(&self, op: usize, tier: usize) -> u64 {
        self.calls[op][tier].get()
    }

    pub fn bytes(&self, op: usize, tier: usize) -> u64 {
        self.bytes[op][tier].get()
    }

    /// Decoded bytes summed over every (op, tier) cell.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().flatten().map(Counter::get).sum()
    }

    /// Calls summed over every (op, tier) cell.
    pub fn total_calls(&self) -> u64 {
        self.calls.iter().flatten().map(Counter::get).sum()
    }
}

impl Default for KernelTelemetry {
    fn default() -> Self {
        KernelTelemetry::new()
    }
}

/// Fleet (S9) counters: sessions, chunked transfers, resume economics,
/// the zoo-wide section cache, and policy advice issued per direction.
#[derive(Debug)]
pub struct FleetTelemetry {
    /// Distinct device sessions registered via `hello`.
    pub sessions: Counter,
    pub chunks_sent: Counter,
    pub chunk_bytes_sent: Counter,
    /// Client bytes *kept* across a reconnect (resumed from the server's
    /// acked offset instead of re-pulled).
    pub resumed_bytes: Counter,
    /// Client bytes discarded on reconnect (past the acked offset, so
    /// they must be re-pulled — the waste a resume bounds).
    pub restarted_bytes: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub cache_evictions: Counter,
    pub advice_upgrade: Counter,
    pub advice_downgrade: Counter,
    pub advice_stay: Counter,
}

impl FleetTelemetry {
    pub const fn new() -> FleetTelemetry {
        FleetTelemetry {
            sessions: Counter::new(),
            chunks_sent: Counter::new(),
            chunk_bytes_sent: Counter::new(),
            resumed_bytes: Counter::new(),
            restarted_bytes: Counter::new(),
            cache_hits: Counter::new(),
            cache_misses: Counter::new(),
            cache_evictions: Counter::new(),
            advice_upgrade: Counter::new(),
            advice_downgrade: Counter::new(),
            advice_stay: Counter::new(),
        }
    }
}

impl Default for FleetTelemetry {
    fn default() -> Self {
        FleetTelemetry::new()
    }
}

/// Serving (S10) counters: cross-tenant aggregates of the per-tenant
/// [`Metrics`], plus queue depth and eviction-forced downgrades.
#[derive(Debug)]
pub struct ServingTelemetry {
    pub requests: Counter,
    pub batches: Counter,
    pub errors: Counter,
    pub upgrades: Counter,
    pub downgrades: Counter,
    /// Downgrades forced by budget eviction (not policy advice).
    pub forced_downgrades: Counter,
    pub page_in_bytes: Counter,
    pub page_out_bytes: Counter,
    /// Requests enqueued but not yet executed, across all tenants.
    pub queue_depth: Gauge,
    pub request_latency: LatencyHisto,
    pub batch_latency: LatencyHisto,
    pub switch_latency: LatencyHisto,
}

impl ServingTelemetry {
    pub const fn new() -> ServingTelemetry {
        ServingTelemetry {
            requests: Counter::new(),
            batches: Counter::new(),
            errors: Counter::new(),
            upgrades: Counter::new(),
            downgrades: Counter::new(),
            forced_downgrades: Counter::new(),
            page_in_bytes: Counter::new(),
            page_out_bytes: Counter::new(),
            queue_depth: Gauge::new(),
            request_latency: LatencyHisto::new(),
            batch_latency: LatencyHisto::new(),
            switch_latency: LatencyHisto::new(),
        }
    }
}

impl Default for ServingTelemetry {
    fn default() -> Self {
        ServingTelemetry::new()
    }
}

/// Reactor (S14) counters: accepted/active connections, cross-thread
/// wakeups, scheduler queue depth per priority class, and token-bucket
/// rate-limit drops. One block serves both reactor-backed servers (the
/// coordinator router and the fleet distributor) — the registry is
/// process-global like every other subsystem here.
#[derive(Debug)]
pub struct ReactorTelemetry {
    /// Sockets accepted by reactor accept loops.
    pub accepts: Counter,
    /// Connections currently registered with a reactor.
    pub active_connections: Gauge,
    /// Cross-thread wakeups delivered through a reactor's waker pipe.
    pub wakeups: Counter,
    /// Jobs queued but not yet claimed, per priority class.
    pub queue_depth_control: Gauge,
    pub queue_depth_switch: Gauge,
    pub queue_depth_infer: Gauge,
    /// Requests refused by a per-device token bucket.
    pub rate_limited: Counter,
}

impl ReactorTelemetry {
    pub const fn new() -> ReactorTelemetry {
        ReactorTelemetry {
            accepts: Counter::new(),
            active_connections: Gauge::new(),
            wakeups: Counter::new(),
            queue_depth_control: Gauge::new(),
            queue_depth_switch: Gauge::new(),
            queue_depth_infer: Gauge::new(),
            rate_limited: Counter::new(),
        }
    }

    /// Queue-depth gauge for a priority class index (0 = control,
    /// 1 = switch, 2 = infer — matching `reactor::queue::Priority`).
    pub fn queue_depth(&self, class: usize) -> &Gauge {
        match class {
            0 => &self.queue_depth_control,
            1 => &self.queue_depth_switch,
            _ => &self.queue_depth_infer,
        }
    }
}

impl Default for ReactorTelemetry {
    fn default() -> Self {
        ReactorTelemetry::new()
    }
}

/// Faults (S15) counters: failpoint fires (total and per site), shed
/// requests, and isolated worker panics. The per-site ledger survives
/// `faults::clear()`, so a chaos run's schedule stays scrapeable after
/// the faults are disarmed.
#[derive(Debug)]
pub struct FaultTelemetry {
    /// Failpoint fires across all sites (`nq_faults_fired_total`).
    pub fired_total: Counter,
    /// Requests refused by queue-depth admission control or an open
    /// circuit breaker (`nq_shed_total`).
    pub shed_total: Counter,
    /// Worker-job panics caught and isolated by the pool
    /// (`nq_worker_panics_total`).
    pub worker_panics: Counter,
    /// Per-site fire counts; rare-path only (one short lock per fire).
    per_site: Mutex<BTreeMap<String, u64>>,
}

impl FaultTelemetry {
    pub const fn new() -> FaultTelemetry {
        FaultTelemetry {
            fired_total: Counter::new(),
            shed_total: Counter::new(),
            worker_panics: Counter::new(),
            per_site: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one fire at `site`.
    pub fn site_fired(&self, site: &str) {
        self.fired_total.inc();
        let mut g = self.per_site.lock().unwrap_or_else(|e| e.into_inner());
        *g.entry(site.to_string()).or_insert(0) += 1;
    }

    /// Per-site fire counts, sorted by site name.
    pub fn sites(&self) -> Vec<(String, u64)> {
        let g = self.per_site.lock().unwrap_or_else(|e| e.into_inner());
        g.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }
}

impl Default for FaultTelemetry {
    fn default() -> Self {
        FaultTelemetry::new()
    }
}

// ---------------------------------------------------------------------------
// trace ring
// ---------------------------------------------------------------------------

/// Typed rare-path events carried by the [`TraceRing`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// A section became resident (A or B page-in).
    PageIn,
    /// A section was released (page-out).
    PageOut,
    /// The store budget evicted a victim tenant's Section B.
    Eviction,
    /// A bitwidth switch (upgrade/downgrade) was applied.
    Switch,
    /// A CRC integrity check refused section bytes.
    CrcFailure,
    /// An `mmap` attempt failed; the source degraded to positioned
    /// reads.
    MapFault,
    /// A chunked transfer was interrupted and retried/resumed.
    ChunkRetry,
    /// Kernel dispatch-tier selection (plan resolution, not per call).
    KernelDispatch,
    /// A weighted-fair scheduler decision (tenant pick, deficit state).
    Fairness,
    /// An armed failpoint fired (site + action).
    FaultFired,
    /// A worker-job panic was caught and isolated by the pool.
    WorkerPanic,
    /// A request was shed by admission control (queue depth cap).
    Shed,
    /// A circuit-breaker state transition (open / half-open / closed).
    Breaker,
}

impl TraceKind {
    pub fn label(self) -> &'static str {
        match self {
            TraceKind::PageIn => "page_in",
            TraceKind::PageOut => "page_out",
            TraceKind::Eviction => "eviction",
            TraceKind::Switch => "switch",
            TraceKind::CrcFailure => "crc_failure",
            TraceKind::MapFault => "map_fault",
            TraceKind::ChunkRetry => "chunk_retry",
            TraceKind::KernelDispatch => "kernel_dispatch",
            TraceKind::Fairness => "fairness",
            TraceKind::FaultFired => "fault_fired",
            TraceKind::WorkerPanic => "worker_panic",
            TraceKind::Shed => "shed",
            TraceKind::Breaker => "breaker",
        }
    }

    pub fn from_label(s: &str) -> Option<TraceKind> {
        Some(match s {
            "page_in" => TraceKind::PageIn,
            "page_out" => TraceKind::PageOut,
            "eviction" => TraceKind::Eviction,
            "switch" => TraceKind::Switch,
            "crc_failure" => TraceKind::CrcFailure,
            "map_fault" => TraceKind::MapFault,
            "chunk_retry" => TraceKind::ChunkRetry,
            "kernel_dispatch" => TraceKind::KernelDispatch,
            "fairness" => TraceKind::Fairness,
            "fault_fired" => TraceKind::FaultFired,
            "worker_panic" => TraceKind::WorkerPanic,
            "shed" => TraceKind::Shed,
            "breaker" => TraceKind::Breaker,
            _ => return None,
        })
    }
}

/// One traced event: wall-clock millisecond timestamp + kind + free text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Milliseconds since the UNIX epoch, stamped at push time.
    pub at_ms: u64,
    pub kind: TraceKind,
    pub detail: String,
}

/// Ring capacity: old events fall off the front.
pub const TRACE_CAP: usize = 1024;

/// Bounded ring buffer of rare-path events, gated by one `AtomicBool`.
/// Disabled (the default), a [`nq_trace!`] call is a single relaxed
/// load — no formatting, no allocation, no lock.
#[derive(Debug)]
pub struct TraceRing {
    enabled: AtomicBool,
    events: Mutex<VecDeque<TraceEvent>>,
}

impl TraceRing {
    pub const fn new() -> TraceRing {
        TraceRing {
            enabled: AtomicBool::new(false),
            events: Mutex::new(VecDeque::new()),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// Append one event (drops the oldest at capacity). Callers should
    /// gate on [`TraceRing::is_enabled`] — [`nq_trace!`] does — so the
    /// detail string is never built when tracing is off; `push` re-checks
    /// the gate anyway.
    pub fn push(&self, kind: TraceKind, detail: String) {
        if !self.is_enabled() {
            return;
        }
        let at_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut g = self.events.lock().unwrap();
        if g.len() == TRACE_CAP {
            g.pop_front();
        }
        g.push_back(TraceEvent { at_ms, kind, detail });
    }

    /// The most recent `n` events, oldest first.
    pub fn tail(&self, n: usize) -> Vec<TraceEvent> {
        let g = self.events.lock().unwrap();
        g.iter().skip(g.len().saturating_sub(n)).cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.lock().unwrap().is_empty()
    }

    pub fn clear(&self) {
        self.events.lock().unwrap().clear();
    }
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new()
    }
}

/// Record a [`TraceEvent`] into the global ring iff tracing is enabled.
/// The gate is checked before the format arguments are evaluated, which
/// is the zero-cost-when-disabled guarantee.
#[macro_export]
macro_rules! nq_trace {
    ($kind:expr, $($arg:tt)*) => {
        if $crate::telemetry::registry().trace.is_enabled() {
            $crate::telemetry::registry()
                .trace
                .push($kind, format!($($arg)*));
        }
    };
}

// ---------------------------------------------------------------------------
// the global registry
// ---------------------------------------------------------------------------

/// The process-global telemetry registry: every subsystem records here,
/// every scrape surface reads from here.
#[derive(Debug)]
pub struct Registry {
    pub store: StoreTelemetry,
    pub kernels: KernelTelemetry,
    pub fleet: FleetTelemetry,
    pub serving: ServingTelemetry,
    pub reactor: ReactorTelemetry,
    pub faults: FaultTelemetry,
    pub trace: TraceRing,
}

impl Registry {
    pub const fn new() -> Registry {
        Registry {
            store: StoreTelemetry::new(),
            kernels: KernelTelemetry::new(),
            fleet: FleetTelemetry::new(),
            serving: ServingTelemetry::new(),
            reactor: ReactorTelemetry::new(),
            faults: FaultTelemetry::new(),
            trace: TraceRing::new(),
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

static REGISTRY: Registry = Registry::new();

/// The process-global registry (const-constructed: no init branch, no
/// lock — a plain `&'static`).
#[inline]
pub fn registry() -> &'static Registry {
    &REGISTRY
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_records_and_quantiles() {
        let h = LatencyHisto::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) >= 80 && h.quantile_us(0.5) <= 512);
        assert!(h.quantile_us(0.99) >= 65536);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn histo_empty() {
        let h = LatencyHisto::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::default();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_occupancy_sum.fetch_add(5, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests=5"));
        assert!(s.contains("occupancy=2.50"));
    }

    #[test]
    fn counters_and_gauges_accumulate() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let g = Gauge::new();
        g.add(100);
        g.sub(30);
        g.inc();
        g.dec();
        assert_eq!(g.get(), 70);
    }

    #[test]
    fn kernel_cells_are_independent() {
        let k = KernelTelemetry::new();
        k.record(OP_UNPACK_DEQUANT, 0, 400);
        k.record(OP_UNPACK_DEQUANT, 2, 800);
        k.record(OP_RECOMPOSE_DEQUANT, 1, 100);
        assert_eq!(k.calls(OP_UNPACK_DEQUANT, 0), 1);
        assert_eq!(k.bytes(OP_UNPACK_DEQUANT, 2), 800);
        assert_eq!(k.calls(OP_UNPACK_INTS, 0), 0);
        assert_eq!(k.total_bytes(), 1300);
        assert_eq!(k.total_calls(), 3);
    }

    #[test]
    fn trace_ring_gates_and_bounds() {
        let t = TraceRing::new();
        // disabled: pushes are dropped at the gate
        t.push(TraceKind::Eviction, "dropped".into());
        assert!(t.is_empty());
        t.enable();
        for i in 0..(TRACE_CAP + 10) {
            t.push(TraceKind::Switch, format!("ev{i}"));
        }
        assert_eq!(t.len(), TRACE_CAP);
        let tail = t.tail(2);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[1].detail, format!("ev{}", TRACE_CAP + 9));
        assert_eq!(tail[1].kind, TraceKind::Switch);
        t.disable();
        t.push(TraceKind::Switch, "late".into());
        assert_eq!(t.len(), TRACE_CAP);
        t.clear();
        assert!(t.is_empty());
    }

    #[test]
    fn trace_kind_labels_roundtrip() {
        for k in [
            TraceKind::PageIn,
            TraceKind::PageOut,
            TraceKind::Eviction,
            TraceKind::Switch,
            TraceKind::CrcFailure,
            TraceKind::MapFault,
            TraceKind::ChunkRetry,
            TraceKind::KernelDispatch,
            TraceKind::Fairness,
            TraceKind::FaultFired,
            TraceKind::WorkerPanic,
            TraceKind::Shed,
            TraceKind::Breaker,
        ] {
            assert_eq!(TraceKind::from_label(k.label()), Some(k));
        }
        assert_eq!(TraceKind::from_label("nope"), None);
    }

    #[test]
    fn fault_telemetry_keeps_a_per_site_ledger() {
        let f = FaultTelemetry::new();
        f.site_fired("a.b");
        f.site_fired("a.b");
        f.site_fired("c.d");
        assert_eq!(f.fired_total.get(), 3);
        assert_eq!(
            f.sites(),
            vec![("a.b".to_string(), 2), ("c.d".to_string(), 1)]
        );
    }

    #[test]
    fn global_registry_is_reachable() {
        // one static instance; deltas accumulate across calls
        let before = registry().store.archive_opens.get();
        registry().store.archive_opens.inc();
        assert_eq!(registry().store.archive_opens.get(), before + 1);
    }
}
