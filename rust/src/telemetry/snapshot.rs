//! Scrape surface for the telemetry registry: one gathered [`Snapshot`]
//! serves all three exposure paths — the versioned JSON `metrics` wire
//! command, the Prometheus text-exposition renderer, and the human
//! `nestquant top` table. The CLI scrapes JSON and renders locally from
//! the parsed snapshot, so every surface reports identical totals.

use std::fmt::Write as _;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::util::json::{self, Value};

use super::{registry, KERNEL_OPS, KERNEL_TIERS, LatencyHisto, Metrics, TraceEvent, TraceKind};

/// Wire format version of the JSON snapshot.
pub const SNAPSHOT_VERSION: u64 = 1;

/// How many trace events a snapshot carries.
const TRACE_TAIL: usize = 64;

/// Point-in-time digest of one [`LatencyHisto`] (quantiles are computed
/// at gather time server-side; buckets never cross the wire).
#[derive(Debug, Clone, PartialEq)]
pub struct HistoSnapshot {
    pub name: String,
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// Point-in-time digest of one tenant's [`Metrics`].
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSnapshot {
    pub id: String,
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub upgrades: u64,
    pub downgrades: u64,
    pub page_in_bytes: u64,
    pub page_out_bytes: u64,
    pub request_mean_us: f64,
    pub request_p50_us: u64,
    pub request_p99_us: u64,
    pub request_max_us: u64,
    pub switch_p99_us: u64,
    /// Circuit-breaker state: 0 closed, 1 open, 2 half-open.
    pub breaker_state: u64,
}

/// A versioned, self-contained scrape of the global registry plus the
/// serving layer's per-tenant metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub version: u64,
    /// Monotonic counters, canonical order, `nq_`-prefixed names.
    pub counters: Vec<(String, u64)>,
    /// Instantaneous gauges, same naming scheme.
    pub gauges: Vec<(String, u64)>,
    pub histograms: Vec<HistoSnapshot>,
    pub tenants: Vec<TenantSnapshot>,
    /// Per-failpoint-site fire counts, sorted by site name (rendered as
    /// the labelled `nq_faults_site_fired_total` Prometheus family).
    pub faults_by_site: Vec<(String, u64)>,
    /// Most recent trace events, oldest first (empty when disabled).
    pub trace: Vec<TraceEvent>,
}

fn histo_digest(name: &str, h: &LatencyHisto) -> HistoSnapshot {
    HistoSnapshot {
        name: name.to_string(),
        count: h.count(),
        mean_us: h.mean_us(),
        p50_us: h.quantile_us(0.5),
        p99_us: h.quantile_us(0.99),
        max_us: h.max_us(),
    }
}

impl Snapshot {
    /// Gather the global registry plus the given per-tenant metrics.
    pub fn gather(tenants: &[(String, Arc<Metrics>)]) -> Snapshot {
        Snapshot::gather_full(tenants, &[])
    }

    /// [`Snapshot::gather`] with extra server-local histograms (e.g. the
    /// fleet server's transfer latency).
    pub fn gather_full(
        tenants: &[(String, Arc<Metrics>)],
        extra_histograms: &[(&str, &LatencyHisto)],
    ) -> Snapshot {
        let r = registry();
        let mut counters: Vec<(String, u64)> = Vec::with_capacity(64);
        let mut c = |name: &str, v: u64| counters.push((name.to_string(), v));

        c("nq_store_archive_opens", r.store.archive_opens.get());
        c("nq_store_crc_failures", r.store.crc_failures.get());
        c("nq_store_a_fetches", r.store.a_fetches.get());
        c("nq_store_b_fetches", r.store.b_fetches.get());
        c("nq_store_a_bytes_fetched", r.store.a_bytes_fetched.get());
        c("nq_store_b_bytes_fetched", r.store.b_bytes_fetched.get());
        c("nq_store_b_releases", r.store.b_releases.get());
        c("nq_store_evictions", r.store.evictions.get());
        c("nq_store_evicted_bytes", r.store.evicted_bytes.get());
        c("nq_store_map_faults", r.store.map_faults.get());

        for (oi, op) in KERNEL_OPS.iter().enumerate() {
            for (ti, tier) in KERNEL_TIERS.iter().enumerate() {
                c(
                    &format!("nq_kernel_{op}_{tier}_calls"),
                    r.kernels.calls(oi, ti),
                );
                c(
                    &format!("nq_kernel_{op}_{tier}_bytes"),
                    r.kernels.bytes(oi, ti),
                );
            }
        }

        c("nq_fleet_sessions", r.fleet.sessions.get());
        c("nq_fleet_chunks_sent", r.fleet.chunks_sent.get());
        c("nq_fleet_chunk_bytes_sent", r.fleet.chunk_bytes_sent.get());
        c("nq_fleet_resumed_bytes", r.fleet.resumed_bytes.get());
        c("nq_fleet_restarted_bytes", r.fleet.restarted_bytes.get());
        c("nq_fleet_cache_hits", r.fleet.cache_hits.get());
        c("nq_fleet_cache_misses", r.fleet.cache_misses.get());
        c("nq_fleet_cache_evictions", r.fleet.cache_evictions.get());
        c("nq_fleet_advice_upgrade", r.fleet.advice_upgrade.get());
        c("nq_fleet_advice_downgrade", r.fleet.advice_downgrade.get());
        c("nq_fleet_advice_stay", r.fleet.advice_stay.get());

        c("nq_serving_requests", r.serving.requests.get());
        c("nq_serving_batches", r.serving.batches.get());
        c("nq_serving_errors", r.serving.errors.get());
        c("nq_serving_upgrades", r.serving.upgrades.get());
        c("nq_serving_downgrades", r.serving.downgrades.get());
        c("nq_serving_forced_downgrades", r.serving.forced_downgrades.get());
        c("nq_serving_page_in_bytes", r.serving.page_in_bytes.get());
        c("nq_serving_page_out_bytes", r.serving.page_out_bytes.get());

        c("nq_reactor_accepts", r.reactor.accepts.get());
        c("nq_reactor_wakeups", r.reactor.wakeups.get());
        c("nq_reactor_rate_limited", r.reactor.rate_limited.get());

        c("nq_faults_fired_total", r.faults.fired_total.get());
        c("nq_shed_total", r.faults.shed_total.get());
        c("nq_worker_panics_total", r.faults.worker_panics.get());

        let gauges = vec![
            (
                "nq_store_resident_a_bytes".to_string(),
                r.store.resident_a_bytes.get(),
            ),
            (
                "nq_store_resident_b_bytes".to_string(),
                r.store.resident_b_bytes.get(),
            ),
            (
                "nq_store_mapped_bytes".to_string(),
                r.store.mapped_bytes.get(),
            ),
            (
                "nq_serving_queue_depth".to_string(),
                r.serving.queue_depth.get(),
            ),
            (
                "nq_reactor_active_connections".to_string(),
                r.reactor.active_connections.get(),
            ),
            (
                "nq_reactor_queue_depth_control".to_string(),
                r.reactor.queue_depth_control.get(),
            ),
            (
                "nq_reactor_queue_depth_switch".to_string(),
                r.reactor.queue_depth_switch.get(),
            ),
            (
                "nq_reactor_queue_depth_infer".to_string(),
                r.reactor.queue_depth_infer.get(),
            ),
        ];

        let mut histograms = vec![
            histo_digest("nq_serving_request_latency", &r.serving.request_latency),
            histo_digest("nq_serving_batch_latency", &r.serving.batch_latency),
            histo_digest("nq_serving_switch_latency", &r.serving.switch_latency),
        ];
        for (name, h) in extra_histograms {
            histograms.push(histo_digest(name, h));
        }

        let mut tsnaps: Vec<TenantSnapshot> = tenants
            .iter()
            .map(|(id, m)| TenantSnapshot {
                id: id.clone(),
                requests: m.requests.load(std::sync::atomic::Ordering::Relaxed),
                batches: m.batches.load(std::sync::atomic::Ordering::Relaxed),
                errors: m.errors.load(std::sync::atomic::Ordering::Relaxed),
                upgrades: m.upgrades.load(std::sync::atomic::Ordering::Relaxed),
                downgrades: m.downgrades.load(std::sync::atomic::Ordering::Relaxed),
                page_in_bytes: m.page_in_bytes.load(std::sync::atomic::Ordering::Relaxed),
                page_out_bytes: m.page_out_bytes.load(std::sync::atomic::Ordering::Relaxed),
                request_mean_us: m.request_latency.mean_us(),
                request_p50_us: m.request_latency.quantile_us(0.5),
                request_p99_us: m.request_latency.quantile_us(0.99),
                request_max_us: m.request_latency.max_us(),
                switch_p99_us: m.switch_latency.quantile_us(0.99),
                breaker_state: m.breaker_state.load(std::sync::atomic::Ordering::Relaxed),
            })
            .collect();
        tsnaps.sort_by(|a, b| a.id.cmp(&b.id));

        Snapshot {
            version: SNAPSHOT_VERSION,
            counters,
            gauges,
            histograms,
            tenants: tsnaps,
            faults_by_site: r.faults.sites(),
            trace: r.trace.tail(TRACE_TAIL),
        }
    }

    /// Look up a counter by canonical name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }

    /// Look up a gauge by canonical name.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Look up a histogram digest by canonical name.
    pub fn histogram(&self, name: &str) -> Option<&HistoSnapshot> {
        self.histograms.iter().find(|h| h.name == name)
    }

    /// Look up a tenant digest by id.
    pub fn tenant(&self, id: &str) -> Option<&TenantSnapshot> {
        self.tenants.iter().find(|t| t.id == id)
    }

    // -- JSON wire format ---------------------------------------------------

    /// Serialize as compact JSON (the `metrics` wire-command payload).
    /// Counter values ride as [`json::uint`] — exact for the full u64
    /// range (byte counters can legitimately pass 2^53; the old f64
    /// detour silently corrupted them there).
    pub fn to_json(&self) -> String {
        let kv_obj = |kv: &[(String, u64)]| {
            Value::Object(
                kv.iter()
                    .map(|(k, v)| (k.clone(), json::uint(*v)))
                    .collect(),
            )
        };
        let histos = self
            .histograms
            .iter()
            .map(|h| {
                json::obj(vec![
                    ("name", json::str_(h.name.clone())),
                    ("count", json::uint(h.count)),
                    ("mean_us", json::num(h.mean_us)),
                    ("p50_us", json::uint(h.p50_us)),
                    ("p99_us", json::uint(h.p99_us)),
                    ("max_us", json::uint(h.max_us)),
                ])
            })
            .collect();
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("id", json::str_(t.id.clone())),
                    ("requests", json::uint(t.requests)),
                    ("batches", json::uint(t.batches)),
                    ("errors", json::uint(t.errors)),
                    ("upgrades", json::uint(t.upgrades)),
                    ("downgrades", json::uint(t.downgrades)),
                    ("page_in_bytes", json::uint(t.page_in_bytes)),
                    ("page_out_bytes", json::uint(t.page_out_bytes)),
                    ("request_mean_us", json::num(t.request_mean_us)),
                    ("request_p50_us", json::uint(t.request_p50_us)),
                    ("request_p99_us", json::uint(t.request_p99_us)),
                    ("request_max_us", json::uint(t.request_max_us)),
                    ("switch_p99_us", json::uint(t.switch_p99_us)),
                    ("breaker_state", json::uint(t.breaker_state)),
                ])
            })
            .collect();
        let trace = self
            .trace
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("at_ms", json::uint(e.at_ms)),
                    ("kind", json::str_(e.kind.label())),
                    ("detail", json::str_(e.detail.clone())),
                ])
            })
            .collect();
        json::to_string(&json::obj(vec![
            ("version", json::uint(self.version)),
            ("counters", kv_obj(&self.counters)),
            ("gauges", kv_obj(&self.gauges)),
            ("histograms", json::arr(histos)),
            ("tenants", json::arr(tenants)),
            ("faults_by_site", kv_obj(&self.faults_by_site)),
            ("trace", json::arr(trace)),
        ]))
    }

    /// Parse a snapshot back from its JSON wire form.
    pub fn from_json(src: &str) -> Result<Snapshot> {
        let v = json::parse(src)?;
        let version = v.path(&["version"])?.as_u64()?;
        ensure!(
            version == SNAPSHOT_VERSION,
            "unsupported metrics snapshot version {version} (expected {SNAPSHOT_VERSION})"
        );
        let kv_list = |key: &str| -> Result<Vec<(String, u64)>> {
            v.path(&[key])?
                .as_object()?
                .iter()
                .map(|(k, val)| Ok((k.clone(), val.as_u64()?)))
                .collect()
        };
        let histograms = v
            .path(&["histograms"])?
            .as_array()?
            .iter()
            .map(|h| {
                Ok(HistoSnapshot {
                    name: h.path(&["name"])?.as_str()?.to_string(),
                    count: h.path(&["count"])?.as_u64()?,
                    mean_us: h.path(&["mean_us"])?.as_f64()?,
                    p50_us: h.path(&["p50_us"])?.as_u64()?,
                    p99_us: h.path(&["p99_us"])?.as_u64()?,
                    max_us: h.path(&["max_us"])?.as_u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let tenants = v
            .path(&["tenants"])?
            .as_array()?
            .iter()
            .map(|t| {
                Ok(TenantSnapshot {
                    id: t.path(&["id"])?.as_str()?.to_string(),
                    requests: t.path(&["requests"])?.as_u64()?,
                    batches: t.path(&["batches"])?.as_u64()?,
                    errors: t.path(&["errors"])?.as_u64()?,
                    upgrades: t.path(&["upgrades"])?.as_u64()?,
                    downgrades: t.path(&["downgrades"])?.as_u64()?,
                    page_in_bytes: t.path(&["page_in_bytes"])?.as_u64()?,
                    page_out_bytes: t.path(&["page_out_bytes"])?.as_u64()?,
                    request_mean_us: t.path(&["request_mean_us"])?.as_f64()?,
                    request_p50_us: t.path(&["request_p50_us"])?.as_u64()?,
                    request_p99_us: t.path(&["request_p99_us"])?.as_u64()?,
                    request_max_us: t.path(&["request_max_us"])?.as_u64()?,
                    switch_p99_us: t.path(&["switch_p99_us"])?.as_u64()?,
                    breaker_state: t.path(&["breaker_state"])?.as_u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let trace = v
            .path(&["trace"])?
            .as_array()?
            .iter()
            .map(|e| {
                let kind = e.path(&["kind"])?.as_str()?;
                Ok(TraceEvent {
                    at_ms: e.path(&["at_ms"])?.as_u64()?,
                    kind: TraceKind::from_label(kind)
                        .ok_or_else(|| anyhow!("unknown trace kind {kind:?}"))?,
                    detail: e.path(&["detail"])?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Snapshot {
            version,
            counters: kv_list("counters")?,
            gauges: kv_list("gauges")?,
            histograms,
            tenants,
            faults_by_site: kv_list("faults_by_site")?,
            trace,
        })
    }

    // -- Prometheus text exposition -----------------------------------------

    /// Render Prometheus text-exposition format (one HELP + TYPE header
    /// per metric family, per-tenant families labelled `tenant="..."`).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            family(&mut out, name, "counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            family(&mut out, name, "gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for h in &self.histograms {
            let n = &h.name;
            family(&mut out, &format!("{n}_count"), "counter");
            let _ = writeln!(out, "{n}_count {}", h.count);
            for (suffix, v) in [("p50_us", h.p50_us), ("p99_us", h.p99_us), ("max_us", h.max_us)] {
                family(&mut out, &format!("{n}_{suffix}"), "gauge");
                let _ = writeln!(out, "{n}_{suffix} {v}");
            }
            family(&mut out, &format!("{n}_mean_us"), "gauge");
            let _ = writeln!(out, "{n}_mean_us {}", h.mean_us);
        }
        if !self.faults_by_site.is_empty() {
            family(&mut out, "nq_faults_site_fired_total", "counter");
            for (site, n) in &self.faults_by_site {
                let _ = writeln!(
                    out,
                    "nq_faults_site_fired_total{{site=\"{}\"}} {n}",
                    escape_label(site)
                );
            }
        }
        if !self.tenants.is_empty() {
            let fields: [(&str, &str, fn(&TenantSnapshot) -> u64); 9] = [
                ("nq_tenant_requests", "counter", |t| t.requests),
                ("nq_tenant_errors", "counter", |t| t.errors),
                ("nq_tenant_upgrades", "counter", |t| t.upgrades),
                ("nq_tenant_downgrades", "counter", |t| t.downgrades),
                ("nq_tenant_page_in_bytes", "counter", |t| t.page_in_bytes),
                ("nq_tenant_page_out_bytes", "counter", |t| t.page_out_bytes),
                ("nq_tenant_request_p50_us", "gauge", |t| t.request_p50_us),
                ("nq_tenant_request_p99_us", "gauge", |t| t.request_p99_us),
                ("nq_tenant_breaker_state", "gauge", |t| t.breaker_state),
            ];
            for (name, kind, get) in fields {
                family(&mut out, name, kind);
                for t in &self.tenants {
                    let _ = writeln!(
                        out,
                        "{name}{{tenant=\"{}\"}} {}",
                        escape_label(&t.id),
                        get(t)
                    );
                }
            }
        }
        out
    }

    // -- human table --------------------------------------------------------

    /// Render the one-shot `nestquant top` table.
    pub fn top_table(&self) -> String {
        let c = |n: &str| self.counter(n).unwrap_or(0);
        let g = |n: &str| self.gauge(n).unwrap_or(0);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<16} {:>8} {:>5} {:>5} {:>5} {:>8} {:>8} {:>12} {:>5}",
            "TENANT", "REQ", "ERR", "UP", "DOWN", "P50us", "P99us", "RESIDENT_B", "BRK"
        );
        if self.tenants.is_empty() {
            let _ = writeln!(out, "(no tenants)");
        }
        for t in &self.tenants {
            let brk = match t.breaker_state {
                0 => "ok",
                1 => "open",
                _ => "half",
            };
            let _ = writeln!(
                out,
                "{:<16} {:>8} {:>5} {:>5} {:>5} {:>8} {:>8} {:>12} {:>5}",
                t.id,
                t.requests,
                t.errors,
                t.upgrades,
                t.downgrades,
                t.request_p50_us,
                t.request_p99_us,
                t.page_in_bytes.saturating_sub(t.page_out_bytes),
                brk,
            );
        }
        let _ = writeln!(
            out,
            "store:   residentA={}B residentB={}B mapped={}B evictions={} evicted={}B \
             crc_failures={} map_faults={}",
            g("nq_store_resident_a_bytes"),
            g("nq_store_resident_b_bytes"),
            g("nq_store_mapped_bytes"),
            c("nq_store_evictions"),
            c("nq_store_evicted_bytes"),
            c("nq_store_crc_failures"),
            c("nq_store_map_faults"),
        );
        let mut kernels = String::new();
        for (ti, tier) in KERNEL_TIERS.iter().enumerate() {
            let (mut calls, mut bytes) = (0u64, 0u64);
            for op in KERNEL_OPS.iter() {
                calls += c(&format!("nq_kernel_{op}_{tier}_calls"));
                bytes += c(&format!("nq_kernel_{op}_{tier}_bytes"));
            }
            if ti > 0 {
                kernels.push_str(" | ");
            }
            let _ = write!(kernels, "{tier}={calls}calls/{bytes}B");
        }
        let _ = writeln!(out, "kernels: {kernels}");
        let _ = writeln!(
            out,
            "fleet:   sessions={} chunks={} sent={}B resumed={}B restarted={}B cache hit/miss/evict={}/{}/{}",
            c("nq_fleet_sessions"),
            c("nq_fleet_chunks_sent"),
            c("nq_fleet_chunk_bytes_sent"),
            c("nq_fleet_resumed_bytes"),
            c("nq_fleet_restarted_bytes"),
            c("nq_fleet_cache_hits"),
            c("nq_fleet_cache_misses"),
            c("nq_fleet_cache_evictions"),
        );
        let _ = writeln!(
            out,
            "serving: requests={} batches={} errors={} upgrades={} downgrades={} forced={} queue={}",
            c("nq_serving_requests"),
            c("nq_serving_batches"),
            c("nq_serving_errors"),
            c("nq_serving_upgrades"),
            c("nq_serving_downgrades"),
            c("nq_serving_forced_downgrades"),
            g("nq_serving_queue_depth"),
        );
        let _ = writeln!(
            out,
            "reactor: conns={} accepts={} wakeups={} queue c/s/i={}/{}/{} rate_limited={}",
            g("nq_reactor_active_connections"),
            c("nq_reactor_accepts"),
            c("nq_reactor_wakeups"),
            g("nq_reactor_queue_depth_control"),
            g("nq_reactor_queue_depth_switch"),
            g("nq_reactor_queue_depth_infer"),
            c("nq_reactor_rate_limited"),
        );
        let mut sites = String::new();
        for (site, n) in &self.faults_by_site {
            if !sites.is_empty() {
                sites.push(' ');
            }
            let _ = write!(sites, "{site}={n}");
        }
        let _ = writeln!(
            out,
            "faults:  fired={} shed={} worker_panics={}{}{}",
            c("nq_faults_fired_total"),
            c("nq_shed_total"),
            c("nq_worker_panics_total"),
            if sites.is_empty() { "" } else { " | " },
            sites,
        );
        if !self.trace.is_empty() {
            let _ = writeln!(out, "trace (last {}):", self.trace.len().min(10));
            let skip = self.trace.len().saturating_sub(10);
            for e in self.trace.iter().skip(skip) {
                let _ = writeln!(out, "  [{}] {} {}", e.at_ms, e.kind.label(), e.detail);
            }
        }
        out
    }
}

fn family(out: &mut String, name: &str, kind: &str) {
    let _ = writeln!(out, "# HELP {name} nestquant telemetry {kind}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---------------------------------------------------------------------------
// Prometheus text-format grammar validation (shared by tests and CI)
// ---------------------------------------------------------------------------

fn is_name_char(c: u8, first: bool) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || c == b':' || (!first && c.is_ascii_digit())
}

fn is_label_char(c: u8, first: bool) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || (!first && c.is_ascii_digit())
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.bytes()
            .enumerate()
            .all(|(i, c)| is_name_char(c, i == 0))
}

/// Validate a Prometheus text-exposition document: metric-name charset,
/// HELP/TYPE comment structure, TYPE kinds, samples only after their
/// HELP+TYPE headers, parseable values, and no duplicate series.
pub fn validate_prometheus(text: &str) -> Result<()> {
    use std::collections::{HashMap, HashSet};
    let mut types: HashMap<&str, &str> = HashMap::new();
    let mut helps: HashSet<&str> = HashSet::new();
    let mut series: HashSet<String> = HashSet::new();
    for (idx, line) in text.lines().enumerate() {
        let ln = idx + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if let Some(r) = rest.strip_prefix("HELP ") {
                let (name, help) = r
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("line {ln}: HELP without text"))?;
                ensure!(valid_metric_name(name), "line {ln}: bad metric name {name:?}");
                ensure!(!help.trim().is_empty(), "line {ln}: empty HELP text");
                ensure!(helps.insert(name), "line {ln}: duplicate HELP for {name}");
            } else if let Some(r) = rest.strip_prefix("TYPE ") {
                let (name, kind) = r
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("line {ln}: TYPE without kind"))?;
                ensure!(valid_metric_name(name), "line {ln}: bad metric name {name:?}");
                ensure!(
                    matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                    "line {ln}: bad TYPE kind {kind:?}"
                );
                ensure!(
                    types.insert(name, kind).is_none(),
                    "line {ln}: duplicate TYPE for {name}"
                );
            } else {
                bail!("line {ln}: unknown comment (only HELP/TYPE emitted): {line:?}");
            }
            continue;
        }
        // sample line: name[{label="value",...}] value
        let b = line.as_bytes();
        let mut i = 0;
        while i < b.len() && is_name_char(b[i], i == 0) {
            i += 1;
        }
        ensure!(i > 0, "line {ln}: missing metric name: {line:?}");
        let name = &line[..i];
        let mut labelset = String::new();
        if i < b.len() && b[i] == b'{' {
            i += 1;
            loop {
                let start = i;
                while i < b.len() && is_label_char(b[i], i == start) {
                    i += 1;
                }
                ensure!(i > start, "line {ln}: bad label name");
                let lname = &line[start..i];
                ensure!(
                    i + 1 < b.len() && b[i] == b'=' && b[i + 1] == b'"',
                    "line {ln}: label {lname:?} missing =\"value\""
                );
                i += 2;
                let vstart = i;
                while i < b.len() && b[i] != b'"' {
                    i += if b[i] == b'\\' { 2 } else { 1 };
                }
                ensure!(i < b.len(), "line {ln}: unterminated label value");
                let _ = write!(labelset, "{lname}=\"{}\",", &line[vstart..i]);
                i += 1; // closing quote
                if i < b.len() && b[i] == b',' {
                    i += 1;
                    continue;
                }
                ensure!(
                    i < b.len() && b[i] == b'}',
                    "line {ln}: unterminated label set"
                );
                i += 1;
                break;
            }
        }
        ensure!(
            i < b.len() && b[i] == b' ',
            "line {ln}: missing sample value: {line:?}"
        );
        let value = &line[i + 1..];
        ensure!(
            value.parse::<f64>().is_ok(),
            "line {ln}: unparseable sample value {value:?}"
        );
        ensure!(
            types.contains_key(name),
            "line {ln}: sample for {name} before its TYPE line"
        );
        ensure!(
            helps.contains(name),
            "line {ln}: sample for {name} before its HELP line"
        );
        ensure!(
            series.insert(format!("{name}{{{labelset}}}")),
            "line {ln}: duplicate series {name}{{{labelset}}}"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn fake_tenants() -> Vec<(String, Arc<Metrics>)> {
        let m = Arc::new(Metrics::default());
        m.requests.fetch_add(7, std::sync::atomic::Ordering::Relaxed);
        m.upgrades.fetch_add(2, std::sync::atomic::Ordering::Relaxed);
        m.page_in_bytes
            .fetch_add(4096, std::sync::atomic::Ordering::Relaxed);
        m.request_latency.record(Duration::from_micros(120));
        m.request_latency.record(Duration::from_micros(950));
        vec![("alpha".to_string(), m), ("beta".to_string(), Arc::default())]
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let snap = Snapshot::gather(&fake_tenants());
        let back = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        // and re-serialization is byte-identical: one source of truth
        assert_eq!(back.to_json(), snap.to_json());
    }

    #[test]
    fn version_mismatch_is_refused() {
        let snap = Snapshot::gather(&[]);
        let bumped = snap.to_json().replacen("\"version\":1", "\"version\":99", 1);
        assert!(Snapshot::from_json(&bumped).is_err());
    }

    #[test]
    fn prometheus_output_passes_grammar() {
        let snap = Snapshot::gather(&fake_tenants());
        let text = snap.prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("nq_store_a_fetches"));
        assert!(text.contains("nq_tenant_requests{tenant=\"alpha\"} 7"));
        // the reactor family rides through the same grammar-checked doc
        assert!(text.contains("nq_reactor_accepts"));
        assert!(text.contains("nq_reactor_active_connections"));
        assert!(text.contains("nq_reactor_queue_depth_infer"));
        assert!(text.contains("nq_reactor_rate_limited"));
        // the faults family and the per-tenant breaker gauge too
        assert!(text.contains("nq_faults_fired_total"));
        assert!(text.contains("nq_shed_total"));
        assert!(text.contains("nq_worker_panics_total"));
        assert!(text.contains("nq_tenant_breaker_state{tenant=\"alpha\"} 0"));
    }

    #[test]
    fn per_site_fault_fires_render_as_a_labelled_family() {
        let mut snap = Snapshot::gather(&[]);
        snap.faults_by_site = vec![
            ("fleet.chunk".to_string(), 3),
            ("worker.job".to_string(), 1),
        ];
        let text = snap.prometheus();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("nq_faults_site_fired_total{site=\"fleet.chunk\"} 3"));
        assert!(text.contains("nq_faults_site_fired_total{site=\"worker.job\"} 1"));
        let back = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.faults_by_site, snap.faults_by_site);
        assert!(snap.top_table().contains("fleet.chunk=3"));
    }

    #[test]
    fn grammar_validator_rejects_violations() {
        // sample before HELP/TYPE
        assert!(validate_prometheus("nq_x 1\n").is_err());
        // bad metric name
        assert!(validate_prometheus("# HELP 9bad x\n# TYPE 9bad counter\n9bad 1\n").is_err());
        // bad TYPE kind
        assert!(validate_prometheus("# HELP nq_x x\n# TYPE nq_x banana\nnq_x 1\n").is_err());
        // duplicate series
        let dup = "# HELP nq_x x\n# TYPE nq_x counter\nnq_x 1\nnq_x 2\n";
        assert!(validate_prometheus(dup).is_err());
        // duplicate labelled series
        let dupl = "# HELP nq_x x\n# TYPE nq_x counter\nnq_x{t=\"a\"} 1\nnq_x{t=\"a\"} 2\n";
        assert!(validate_prometheus(dupl).is_err());
        // distinct labels are fine
        let ok = "# HELP nq_x x\n# TYPE nq_x counter\nnq_x{t=\"a\"} 1\nnq_x{t=\"b\"} 2\n";
        validate_prometheus(ok).unwrap();
        // unparseable value
        assert!(validate_prometheus("# HELP nq_x x\n# TYPE nq_x counter\nnq_x one\n").is_err());
    }

    #[test]
    fn top_table_lists_tenants_and_sections() {
        let snap = Snapshot::gather(&fake_tenants());
        let top = snap.top_table();
        assert!(top.contains("alpha"));
        assert!(top.contains("beta"));
        assert!(top.contains("store:"));
        assert!(top.contains("kernels:"));
        assert!(top.contains("serving:"));
        assert!(top.contains("reactor:"));
        assert!(top.contains("faults:"));
        assert!(top.contains("BRK"));
    }
}
