//! Transmission system (S7): the edge-server ↔ device model-push channel
//! the paper measures network traffic on (Figs 13/14, §4.3.1).
//!
//! Length-framed messages over TCP (std::net; tokio is unavailable
//! offline), with a byte meter on both directions. The `fleet_ota`
//! example and `report traffic` run a real localhost round-trip and
//! report *measured wire bytes*, not file sizes — exactly what the
//! paper's prototype TCP/IP socket system reports.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, ensure, Context, Result};

/// Frame types on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Full model push (FP32 / mono / nest container bytes).
    ModelFull = 1,
    /// Section-A-only push (part-bit provisioning).
    ModelPart = 2,
    /// Section-B push (upgrade delta).
    ModelDelta = 3,
    /// Control/ack.
    Control = 4,
}

impl FrameKind {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => FrameKind::ModelFull,
            2 => FrameKind::ModelPart,
            3 => FrameKind::ModelDelta,
            4 => FrameKind::Control,
            _ => bail!("unknown frame kind {v}"),
        })
    }
}

/// One framed message: kind + name + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub name: String,
    pub payload: Vec<u8>,
}

const FRAME_MAGIC: u32 = 0x4E51_5458; // "NQTX"
const MAX_FRAME: u64 = 4 << 30;

/// Bidirectional traffic meter (shared across connections).
#[derive(Debug, Default)]
pub struct Meter {
    pub sent: AtomicU64,
    pub received: AtomicU64,
}

impl Meter {
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.sent.load(Ordering::Relaxed),
            self.received.load(Ordering::Relaxed),
        )
    }
}

/// Write one frame; returns wire bytes written.
pub fn send_frame(stream: &mut impl Write, frame: &Frame, meter: &Meter) -> Result<u64> {
    let name = frame.name.as_bytes();
    ensure!(name.len() < 1 << 16, "name too long");
    let mut header = Vec::with_capacity(16 + name.len());
    header.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    header.push(frame.kind as u8);
    header.extend_from_slice(&(name.len() as u16).to_le_bytes());
    header.extend_from_slice(name);
    header.extend_from_slice(&(frame.payload.len() as u64).to_le_bytes());
    stream.write_all(&header)?;
    stream.write_all(&frame.payload)?;
    stream.flush()?;
    let wire = (header.len() + frame.payload.len()) as u64;
    meter.sent.fetch_add(wire, Ordering::Relaxed);
    Ok(wire)
}

/// Read one frame; returns (frame, wire bytes read).
pub fn recv_frame(stream: &mut impl Read, meter: &Meter) -> Result<(Frame, u64)> {
    let mut fixed = [0u8; 7];
    stream.read_exact(&mut fixed).context("frame header")?;
    let magic = u32::from_le_bytes(fixed[0..4].try_into().unwrap());
    ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#x}");
    let kind = FrameKind::from_u8(fixed[4])?;
    let name_len = u16::from_le_bytes(fixed[5..7].try_into().unwrap()) as usize;
    let mut name = vec![0u8; name_len];
    stream.read_exact(&mut name)?;
    let mut len8 = [0u8; 8];
    stream.read_exact(&mut len8)?;
    let plen = u64::from_le_bytes(len8);
    ensure!(plen <= MAX_FRAME, "frame too large: {plen}");
    let mut payload = vec![0u8; plen as usize];
    stream.read_exact(&mut payload)?;
    let wire = (7 + name_len + 8) as u64 + plen;
    meter.received.fetch_add(wire, Ordering::Relaxed);
    Ok((
        Frame {
            kind,
            name: String::from_utf8(name)?,
            payload,
        },
        wire,
    ))
}

/// The edge-server side: serves model files to connecting devices.
pub struct PushServer {
    pub addr: std::net::SocketAddr,
    pub meter: Arc<Meter>,
    handle: Option<JoinHandle<()>>,
}

impl PushServer {
    /// Serve each queued frame to each accepted connection (one frame
    /// sequence per connection), then stop after `connections` accepts.
    pub fn serve_frames(frames: Vec<Frame>, connections: usize) -> Result<PushServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let meter = Arc::new(Meter::default());
        let m2 = Arc::clone(&meter);
        let handle = std::thread::spawn(move || {
            for _ in 0..connections {
                let Ok((mut sock, _)) = listener.accept() else {
                    return;
                };
                for f in &frames {
                    if send_frame(&mut sock, f, &m2).is_err() {
                        return;
                    }
                }
            }
        });
        Ok(PushServer {
            addr,
            meter,
            handle: Some(handle),
        })
    }

    pub fn join(mut self) -> (u64, u64) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.meter.snapshot()
    }
}

impl Drop for PushServer {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Device side: connect and receive `count` frames.
pub fn pull_frames(addr: std::net::SocketAddr, count: usize, meter: &Meter) -> Result<Vec<Frame>> {
    let mut sock = TcpStream::connect(addr)?;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let (f, _) = recv_frame(&mut sock, meter)?;
        out.push(f);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(kind: FrameKind, name: &str, n: usize) -> Frame {
        Frame {
            kind,
            name: name.into(),
            payload: (0..n).map(|i| (i % 251) as u8).collect(),
        }
    }

    #[test]
    fn frame_roundtrip_in_memory() {
        let meter = Meter::default();
        let f = frame(FrameKind::ModelFull, "cnn_m", 10_000);
        let mut buf = Vec::new();
        let sent = send_frame(&mut buf, &f, &meter).unwrap();
        let (got, recvd) = recv_frame(&mut buf.as_slice(), &meter).unwrap();
        assert_eq!(got, f);
        assert_eq!(sent, recvd);
        assert_eq!(meter.snapshot(), (sent, sent));
    }

    #[test]
    fn tcp_push_pull_meters_match() {
        let frames = vec![
            frame(FrameKind::ModelPart, "m.secA", 5_000),
            frame(FrameKind::ModelDelta, "m.secB", 2_500),
        ];
        let server = PushServer::serve_frames(frames.clone(), 1).unwrap();
        let dev_meter = Meter::default();
        let got = pull_frames(server.addr, 2, &dev_meter).unwrap();
        assert_eq!(got, frames);
        let (sent, _) = server.join();
        let (_, received) = dev_meter.snapshot();
        assert_eq!(sent, received);
        // wire overhead beyond payload is the small frame header only
        let payload: u64 = frames.iter().map(|f| f.payload.len() as u64).sum();
        assert!(sent > payload && sent < payload + 200);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let meter = Meter::default();
        let f = frame(FrameKind::Control, "x", 10);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &meter).unwrap();
        buf[0] ^= 0xFF;
        assert!(recv_frame(&mut buf.as_slice(), &meter).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let meter = Meter::default();
        let f = frame(FrameKind::ModelFull, "x", 1000);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &meter).unwrap();
        let cut = &buf[..buf.len() - 10];
        assert!(recv_frame(&mut &cut[..], &meter).is_err());
    }

    #[test]
    fn multiple_connections() {
        let frames = vec![frame(FrameKind::ModelFull, "m", 1_000)];
        let server = PushServer::serve_frames(frames.clone(), 3).unwrap();
        for _ in 0..3 {
            let m = Meter::default();
            let got = pull_frames(server.addr, 1, &m).unwrap();
            assert_eq!(got, frames);
        }
        let (sent, _) = server.join();
        assert!(sent >= 3_000);
    }
}
