//! Transmission system (S7): the edge-server ↔ device model-push channel
//! the paper measures network traffic on (Figs 13/14, §4.3.1).
//!
//! Length-framed messages over TCP (std::net; tokio is unavailable
//! offline), with a byte meter on both directions. The `fleet_ota`
//! example and `report traffic` run a real localhost round-trip and
//! report *measured wire bytes*, not file sizes — exactly what the
//! paper's prototype TCP/IP socket system reports.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

/// Frame types on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Full model push (FP32 / mono / nest container bytes).
    ModelFull = 1,
    /// Section-A-only push (part-bit provisioning).
    ModelPart = 2,
    /// Section-B push (upgrade delta).
    ModelDelta = 3,
    /// Control/ack.
    Control = 4,
    /// One chunk of a resumable section transfer (fleet paging): payload
    /// is a [`ChunkHeader`] followed by the chunk data.
    Chunk = 5,
    /// Receiver acknowledgement of a chunk: payload is `(xfer_id,
    /// acked_end)` as two LE u64s. The acked offset is the resume point
    /// after an interrupted transfer.
    Ack = 6,
}

impl FrameKind {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => FrameKind::ModelFull,
            2 => FrameKind::ModelPart,
            3 => FrameKind::ModelDelta,
            4 => FrameKind::Control,
            5 => FrameKind::Chunk,
            6 => FrameKind::Ack,
            _ => bail!("unknown frame kind {v}"),
        })
    }
}

/// One framed message: kind + name + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub name: String,
    pub payload: Vec<u8>,
}

const FRAME_MAGIC: u32 = 0x4E51_5458; // "NQTX"
/// Hard ceiling on a single frame's payload length.
pub const MAX_FRAME: u64 = 4 << 30;
/// Never pre-allocate more than this from an untrusted length header; the
/// payload buffer grows as bytes actually arrive.
const MAX_INITIAL_ALLOC: usize = 1 << 20;
/// Copy granularity for the incremental payload read.
const READ_CHUNK: usize = 64 << 10;
/// Fixed frame-header prefix before the name: magic + kind + name_len.
const FIXED_HEADER: usize = 7;
/// Default socket read timeout for pulls: a dead peer cannot hang a
/// device thread forever.
pub const DEFAULT_PULL_TIMEOUT: Duration = Duration::from_secs(30);

/// Idle read-timeout shared by both servers' accept paths: the blocking
/// fleet handler's poll tick and the reactor's wait timeout both use
/// this, so one knob governs how fast either server notices a stop flag
/// or a deadline. Default 100 ms; override with `NQ_READ_TIMEOUT_MS`
/// (milliseconds, > 0; read once per process).
pub fn read_timeout() -> Duration {
    use std::sync::OnceLock;
    static MS: OnceLock<u64> = OnceLock::new();
    Duration::from_millis(*MS.get_or_init(|| {
        std::env::var("NQ_READ_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|&ms| ms > 0)
            .unwrap_or(100)
    }))
}

/// Bidirectional traffic meter (shared across connections).
#[derive(Debug, Default)]
pub struct Meter {
    pub sent: AtomicU64,
    pub received: AtomicU64,
}

impl Meter {
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.sent.load(Ordering::Relaxed),
            self.received.load(Ordering::Relaxed),
        )
    }
}

/// Encode one frame onto the end of `out`; returns its wire length.
/// The single source of truth for the frame layout — [`send_frame`] and
/// [`FrameWriter`] both produce exactly these bytes.
fn encode_frame_into(frame: &Frame, out: &mut Vec<u8>) -> Result<u64> {
    let name = frame.name.as_bytes();
    ensure!(name.len() < 1 << 16, "name too long");
    let wire = FIXED_HEADER + name.len() + 8 + frame.payload.len();
    out.reserve(wire);
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.push(frame.kind as u8);
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name);
    out.extend_from_slice(&(frame.payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&frame.payload);
    Ok(wire as u64)
}

/// Write one frame; returns wire bytes written. Failpoint:
/// `transport.send` (an injected error poses as a broken socket).
pub fn send_frame(stream: &mut impl Write, frame: &Frame, meter: &Meter) -> Result<u64> {
    crate::faults::fail_point("transport.send")?;
    let mut buf = Vec::new();
    let wire = encode_frame_into(frame, &mut buf)?;
    stream.write_all(&buf)?;
    stream.flush()?;
    meter.sent.fetch_add(wire, Ordering::Relaxed);
    Ok(wire)
}

/// Read one frame; returns (frame, wire bytes read).
///
/// Driven by the same incremental [`FrameReader`] the reactor uses, with
/// exact-sized blocking reads: the stream is never read past the end of
/// the returned frame, so callers that interleave `recv_frame` with
/// their own peeking (e.g. a `BufReader` idle poll) keep their buffers
/// coherent.
///
/// Failpoint: `transport.recv` (an injected error poses as a torn read).
pub fn recv_frame(stream: &mut impl Read, meter: &Meter) -> Result<(Frame, u64)> {
    crate::faults::fail_point("transport.recv")?;
    let mut fr = FrameReader::new();
    loop {
        if let Some((frame, wire)) = fr.next_frame()? {
            meter.received.fetch_add(wire, Ordering::Relaxed);
            return Ok((frame, wire));
        }
        fr.fill_from(stream)?;
    }
}

// ---------------------------------------------------------------------------
// incremental (partial-read-tolerant) codec
// ---------------------------------------------------------------------------

/// Result of scanning a buffered frame prefix.
enum Scan {
    /// Bytes missing until the next parse milestone.
    Need(usize),
    /// A complete frame occupies `buf[..total]`.
    Ready { total: usize },
}

/// Validate and measure the frame at the front of `buf`. Rejections are
/// eager: bad magic at 4 bytes, unknown kind at 5, an oversized length
/// header as soon as the 8 length bytes are in.
fn scan(buf: &[u8]) -> Result<Scan> {
    if buf.len() >= 4 {
        let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#x}");
    }
    if buf.len() >= 5 {
        FrameKind::from_u8(buf[4])?;
    }
    if buf.len() < FIXED_HEADER {
        return Ok(Scan::Need(FIXED_HEADER - buf.len()));
    }
    let name_len = u16::from_le_bytes(buf[5..7].try_into().unwrap()) as usize;
    let len_end = FIXED_HEADER + name_len + 8;
    if buf.len() < len_end {
        return Ok(Scan::Need(len_end - buf.len()));
    }
    let plen = u64::from_le_bytes(buf[len_end - 8..len_end].try_into().unwrap());
    ensure!(plen <= MAX_FRAME, "frame too large: {plen}");
    let total = len_end + plen as usize;
    if buf.len() < total {
        return Ok(Scan::Need(total - buf.len()));
    }
    Ok(Scan::Ready { total })
}

/// Incremental frame parser: feed whatever bytes the socket had — any
/// split point is fine, including mid-magic — and take complete frames
/// out. The reactor's connection state machines run on this; the
/// blocking [`recv_frame`] drives the same parser with exact-sized
/// reads. The length header is untrusted: the buffer grows only as
/// bytes actually arrive, capped at [`MAX_INITIAL_ALLOC`] of
/// pre-reservation, so a malicious 4 GiB header costs almost nothing
/// before the stream dies.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Whether capacity for the current frame was already reserved (one
    /// capped reservation per frame, once its length header parses).
    reserved: bool,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Bytes buffered but not yet taken out as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Bytes needed to reach the next parse milestone (header complete,
    /// length known, frame complete). 0 when a full frame is already
    /// buffered or the prefix is invalid (then [`Self::next_frame`]
    /// reports the error). Feeding more than this is fine — the excess
    /// belongs to the next frame.
    pub fn need(&self) -> usize {
        match scan(&self.buf) {
            Ok(Scan::Need(n)) => n,
            _ => 0,
        }
    }

    /// Append raw socket bytes. Prefix validation is eager, so a
    /// poisoned connection fails here rather than at frame completion.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(bytes);
        self.after_feed()
    }

    /// Blocking helper: read exactly the bytes needed to reach the next
    /// parse milestone (capped at [`READ_CHUNK`]) into the buffer. Never
    /// consumes bytes past the current frame.
    pub fn fill_from(&mut self, stream: &mut impl Read) -> Result<()> {
        let want = self.need().min(READ_CHUNK);
        let old = self.buf.len();
        self.buf.resize(old + want, 0);
        if let Err(e) = stream.read_exact(&mut self.buf[old..]) {
            self.buf.truncate(old);
            let stage = if old < FIXED_HEADER {
                "frame header"
            } else {
                "frame payload"
            };
            return Err(e).context(stage);
        }
        self.after_feed()
    }

    fn after_feed(&mut self) -> Result<()> {
        // One capped capacity reservation per frame, as soon as the
        // (untrusted) length header is parseable and sane.
        if !self.reserved && self.buf.len() >= FIXED_HEADER {
            let name_len = u16::from_le_bytes(self.buf[5..7].try_into().unwrap()) as usize;
            let len_end = FIXED_HEADER + name_len + 8;
            if self.buf.len() >= len_end {
                let plen = u64::from_le_bytes(self.buf[len_end - 8..len_end].try_into().unwrap());
                if plen <= MAX_FRAME {
                    let total = len_end + plen as usize;
                    let grow = total
                        .saturating_sub(self.buf.len())
                        .min(MAX_INITIAL_ALLOC);
                    self.buf.reserve(grow);
                    self.reserved = true;
                }
            }
        }
        scan(&self.buf).map(|_| ())
    }

    /// Take the next complete frame, if one is fully buffered. Returns
    /// `(frame, wire_len)`; metering is the caller's job (the reactor
    /// meters on decode, the blocking path in [`recv_frame`]).
    pub fn next_frame(&mut self) -> Result<Option<(Frame, u64)>> {
        let total = match scan(&self.buf)? {
            Scan::Need(_) => return Ok(None),
            Scan::Ready { total } => total,
        };
        let kind = FrameKind::from_u8(self.buf[4])?;
        let name_len = u16::from_le_bytes(self.buf[5..7].try_into().unwrap()) as usize;
        let name = String::from_utf8(self.buf[FIXED_HEADER..FIXED_HEADER + name_len].to_vec())?;
        let payload = self.buf[FIXED_HEADER + name_len + 8..total].to_vec();
        self.buf.drain(..total);
        self.reserved = false;
        Ok(Some((Frame { kind, name, payload }, total as u64)))
    }
}

/// Incremental frame encoder for nonblocking sinks: frames are queued
/// whole (byte-identical to [`send_frame`] — both go through the same
/// private encoder) and flushed as far as the socket will go. A frame
/// is added to the meter exactly when its final byte leaves the buffer,
/// so request/response accounting agrees with the blocking path.
#[derive(Debug, Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
    pos: usize,
    /// Per queued frame: (absolute flushed-offset at which it ends, wire len).
    bounds: std::collections::VecDeque<(u64, u64)>,
    queued_abs: u64,
    flushed_abs: u64,
}

impl FrameWriter {
    pub fn new() -> FrameWriter {
        FrameWriter::default()
    }

    /// Queue one frame for writing.
    pub fn queue(&mut self, frame: &Frame) -> Result<()> {
        let wire = encode_frame_into(frame, &mut self.buf)?;
        self.queued_abs += wire;
        self.bounds.push_back((self.queued_abs, wire));
        Ok(())
    }

    /// Unflushed bytes still queued.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Write as much as the sink accepts. `Ok(true)` when fully drained,
    /// `Ok(false)` when the sink would block.
    pub fn flush_to(&mut self, w: &mut impl Write, meter: &Meter) -> std::io::Result<bool> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "sink accepted 0 bytes",
                    ))
                }
                Ok(n) => {
                    self.pos += n;
                    self.flushed_abs += n as u64;
                    while let Some(&(end, wire)) = self.bounds.front() {
                        if end > self.flushed_abs {
                            break;
                        }
                        meter.sent.fetch_add(wire, Ordering::Relaxed);
                        self.bounds.pop_front();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(true)
    }
}

// ---------------------------------------------------------------------------
// chunked, resumable transfers (fleet paging)
// ---------------------------------------------------------------------------

/// Per-chunk metadata carried at the front of a [`FrameKind::Chunk`]
/// payload. Offsets are relative to the start of the section being
/// transferred, so a resume simply re-enters the stream at the last
/// acked offset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkHeader {
    /// Server-assigned transfer id; echoed back in every ack.
    pub xfer_id: u64,
    /// Byte offset of this chunk within the section.
    pub offset: u64,
    /// Total section length in bytes (constant across the transfer).
    pub total_len: u64,
}

/// Encoded size of a [`ChunkHeader`].
pub const CHUNK_HEADER_LEN: usize = 24;

impl ChunkHeader {
    /// End offset of a chunk carrying `data_len` bytes.
    pub fn end(&self, data_len: usize) -> u64 {
        self.offset + data_len as u64
    }

    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.xfer_id.to_le_bytes());
        out.extend_from_slice(&self.offset.to_le_bytes());
        out.extend_from_slice(&self.total_len.to_le_bytes());
    }

    fn decode(payload: &[u8]) -> Result<ChunkHeader> {
        ensure!(
            payload.len() >= CHUNK_HEADER_LEN,
            "chunk payload too short: {}",
            payload.len()
        );
        let u = |i: usize| u64::from_le_bytes(payload[i..i + 8].try_into().unwrap());
        Ok(ChunkHeader {
            xfer_id: u(0),
            offset: u(8),
            total_len: u(16),
        })
    }
}

/// Build one chunk frame: header + data, named after the transfer.
pub fn chunk_frame(name: &str, header: ChunkHeader, data: &[u8]) -> Frame {
    let mut payload = Vec::with_capacity(CHUNK_HEADER_LEN + data.len());
    header.encode_into(&mut payload);
    payload.extend_from_slice(data);
    Frame {
        kind: FrameKind::Chunk,
        name: name.to_string(),
        payload,
    }
}

/// Split a chunk frame into its header and data slice.
pub fn parse_chunk(frame: &Frame) -> Result<(ChunkHeader, &[u8])> {
    ensure!(
        frame.kind == FrameKind::Chunk,
        "expected Chunk frame, got {:?} ({:?})",
        frame.kind,
        frame.name
    );
    let header = ChunkHeader::decode(&frame.payload)?;
    let data = &frame.payload[CHUNK_HEADER_LEN..];
    ensure!(
        header.end(data.len()) <= header.total_len,
        "chunk [{}, {}) overruns total {}",
        header.offset,
        header.end(data.len()),
        header.total_len
    );
    Ok((header, data))
}

/// Build an ack frame for everything up to (exclusive) `acked_end`.
pub fn ack_frame(xfer_id: u64, acked_end: u64) -> Frame {
    let mut payload = Vec::with_capacity(16);
    payload.extend_from_slice(&xfer_id.to_le_bytes());
    payload.extend_from_slice(&acked_end.to_le_bytes());
    Frame {
        kind: FrameKind::Ack,
        name: "ack".into(),
        payload,
    }
}

/// Decode an ack frame into `(xfer_id, acked_end)`.
pub fn parse_ack(frame: &Frame) -> Result<(u64, u64)> {
    ensure!(
        frame.kind == FrameKind::Ack,
        "expected Ack frame, got {:?} ({:?})",
        frame.kind,
        frame.name
    );
    ensure!(frame.payload.len() == 16, "bad ack payload");
    let xfer = u64::from_le_bytes(frame.payload[0..8].try_into().unwrap());
    let end = u64::from_le_bytes(frame.payload[8..16].try_into().unwrap());
    Ok((xfer, end))
}

// ---------------------------------------------------------------------------
// model-id tagging (multi-tenant serving)
// ---------------------------------------------------------------------------

/// Prefix `data` with a length-tagged model id: `u16 id_len | id | data`.
/// The payload codec of the multi-tenant inference protocol — `infer`
/// requests and `logits` replies both carry the model id so one server
/// socket can route to any hosted model.
pub fn encode_tagged(model: &str, data: &[u8]) -> Result<Vec<u8>> {
    ensure!(model.len() < 1 << 16, "model id too long ({})", model.len());
    let mut out = Vec::with_capacity(2 + model.len() + data.len());
    out.extend_from_slice(&(model.len() as u16).to_le_bytes());
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(data);
    Ok(out)
}

/// Split a tagged payload back into `(model_id, data)`.
pub fn decode_tagged(payload: &[u8]) -> Result<(&str, &[u8])> {
    ensure!(payload.len() >= 2, "tagged payload too short: {}", payload.len());
    let id_len = u16::from_le_bytes(payload[0..2].try_into().unwrap()) as usize;
    ensure!(
        payload.len() >= 2 + id_len,
        "tagged payload truncated: id needs {id_len} bytes, have {}",
        payload.len() - 2
    );
    let model = std::str::from_utf8(&payload[2..2 + id_len]).context("model id")?;
    Ok((model, &payload[2 + id_len..]))
}

/// Encode a model-id listing (the `models` reply payload, shared by the
/// inference and fleet servers): newline-joined ids.
pub fn encode_model_list<S: AsRef<str>>(ids: &[S]) -> Vec<u8> {
    ids.iter()
        .map(|s| s.as_ref())
        .collect::<Vec<_>>()
        .join("\n")
        .into_bytes()
}

/// Decode a `models` reply payload back into ids.
pub fn decode_model_list(payload: &[u8]) -> Result<Vec<String>> {
    Ok(std::str::from_utf8(payload)
        .context("model list")?
        .split('\n')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect())
}

/// True when an error is a socket read timeout (used by pollers that
/// re-check a stop flag on idle).
pub fn is_timeout(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(
            io.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        )
    })
}

/// The edge-server side: serves model files to connecting devices.
pub struct PushServer {
    pub addr: std::net::SocketAddr,
    pub meter: Arc<Meter>,
    handle: Option<JoinHandle<()>>,
}

impl PushServer {
    /// Serve each queued frame to each accepted connection (one frame
    /// sequence per connection), then stop after `connections` accepts.
    pub fn serve_frames(frames: Vec<Frame>, connections: usize) -> Result<PushServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let meter = Arc::new(Meter::default());
        let m2 = Arc::clone(&meter);
        let handle = std::thread::spawn(move || {
            for _ in 0..connections {
                let Ok((mut sock, _)) = listener.accept() else {
                    return;
                };
                for f in &frames {
                    if send_frame(&mut sock, f, &m2).is_err() {
                        return;
                    }
                }
            }
        });
        Ok(PushServer {
            addr,
            meter,
            handle: Some(handle),
        })
    }

    pub fn join(mut self) -> (u64, u64) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.meter.snapshot()
    }
}

impl Drop for PushServer {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Device side: connect and receive `count` frames, with the default
/// read timeout so a dead peer cannot hang the calling thread forever.
pub fn pull_frames(addr: std::net::SocketAddr, count: usize, meter: &Meter) -> Result<Vec<Frame>> {
    pull_frames_timeout(addr, count, meter, Some(DEFAULT_PULL_TIMEOUT))
}

/// [`pull_frames`] with an explicit per-read timeout (`None` blocks
/// indefinitely — only sensible in tests).
pub fn pull_frames_timeout(
    addr: std::net::SocketAddr,
    count: usize,
    meter: &Meter,
    timeout: Option<Duration>,
) -> Result<Vec<Frame>> {
    let mut sock = TcpStream::connect(addr)?;
    sock.set_read_timeout(timeout)?;
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let (f, _) = recv_frame(&mut sock, meter)
            .with_context(|| format!("pulling frame {i}/{count}"))?;
        out.push(f);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(kind: FrameKind, name: &str, n: usize) -> Frame {
        Frame {
            kind,
            name: name.into(),
            payload: (0..n).map(|i| (i % 251) as u8).collect(),
        }
    }

    #[test]
    fn frame_roundtrip_in_memory() {
        let meter = Meter::default();
        let f = frame(FrameKind::ModelFull, "cnn_m", 10_000);
        let mut buf = Vec::new();
        let sent = send_frame(&mut buf, &f, &meter).unwrap();
        let (got, recvd) = recv_frame(&mut buf.as_slice(), &meter).unwrap();
        assert_eq!(got, f);
        assert_eq!(sent, recvd);
        assert_eq!(meter.snapshot(), (sent, sent));
    }

    #[test]
    fn tcp_push_pull_meters_match() {
        let frames = vec![
            frame(FrameKind::ModelPart, "m.secA", 5_000),
            frame(FrameKind::ModelDelta, "m.secB", 2_500),
        ];
        let server = PushServer::serve_frames(frames.clone(), 1).unwrap();
        let dev_meter = Meter::default();
        let got = pull_frames(server.addr, 2, &dev_meter).unwrap();
        assert_eq!(got, frames);
        let (sent, _) = server.join();
        let (_, received) = dev_meter.snapshot();
        assert_eq!(sent, received);
        // wire overhead beyond payload is the small frame header only
        let payload: u64 = frames.iter().map(|f| f.payload.len() as u64).sum();
        assert!(sent > payload && sent < payload + 200);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let meter = Meter::default();
        let f = frame(FrameKind::Control, "x", 10);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &meter).unwrap();
        buf[0] ^= 0xFF;
        assert!(recv_frame(&mut buf.as_slice(), &meter).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let meter = Meter::default();
        let f = frame(FrameKind::ModelFull, "x", 1000);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &meter).unwrap();
        let cut = &buf[..buf.len() - 10];
        assert!(recv_frame(&mut &cut[..], &meter).is_err());
    }

    #[test]
    fn tagged_payload_roundtrip() {
        let p = encode_tagged("cnn_m_n8h4", b"imagebytes").unwrap();
        let (model, data) = decode_tagged(&p).unwrap();
        assert_eq!((model, data), ("cnn_m_n8h4", &b"imagebytes"[..]));
        // empty id routes to the sole tenant; empty data is legal too
        let p = encode_tagged("", &[]).unwrap();
        assert_eq!(decode_tagged(&p).unwrap(), ("", &[][..]));
        // truncated prefixes are clean errors
        assert!(decode_tagged(&[5]).is_err());
        assert!(decode_tagged(&[5, 0, b'a', b'b']).is_err());
    }

    #[test]
    fn model_list_roundtrip() {
        let ids = ["alpha", "beta", "gamma"];
        let back = decode_model_list(&encode_model_list(&ids)).unwrap();
        assert_eq!(back, ids.map(String::from).to_vec());
        assert!(decode_model_list(&encode_model_list::<&str>(&[])).unwrap().is_empty());
        assert!(decode_model_list(&[0xFF, 0xFE]).is_err(), "non-utf8 rejected");
    }

    #[test]
    fn chunk_frame_roundtrip() {
        let header = ChunkHeader {
            xfer_id: 7,
            offset: 4096,
            total_len: 10_000,
        };
        let data: Vec<u8> = (0..1000).map(|i| (i % 253) as u8).collect();
        let f = chunk_frame("m.secB", header, &data);
        let meter = Meter::default();
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &meter).unwrap();
        let (got, _) = recv_frame(&mut buf.as_slice(), &meter).unwrap();
        let (h2, d2) = parse_chunk(&got).unwrap();
        assert_eq!(h2, header);
        assert_eq!(d2, &data[..]);
        assert_eq!(h2.end(d2.len()), 5096);
    }

    #[test]
    fn chunk_overrun_rejected() {
        let header = ChunkHeader {
            xfer_id: 1,
            offset: 900,
            total_len: 1000,
        };
        let f = chunk_frame("x", header, &[0u8; 200]); // 900+200 > 1000
        assert!(parse_chunk(&f).is_err());
    }

    #[test]
    fn ack_roundtrip_and_mismatch() {
        let f = ack_frame(42, 8192);
        assert_eq!(parse_ack(&f).unwrap(), (42, 8192));
        let not_ack = frame(FrameKind::Control, "ack", 16);
        assert!(parse_ack(&not_ack).is_err());
    }

    #[test]
    fn huge_length_header_fails_without_huge_alloc() {
        // A frame header claiming a near-MAX_FRAME payload over a stream
        // that ends immediately must error quickly; the incremental read
        // caps the allocation at MAX_INITIAL_ALLOC rather than trusting
        // the attacker-controlled length.
        let mut buf = Vec::new();
        buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        buf.push(FrameKind::ModelFull as u8);
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'x');
        buf.extend_from_slice(&(MAX_FRAME - 1).to_le_bytes());
        let meter = Meter::default();
        assert!(recv_frame(&mut buf.as_slice(), &meter).is_err());
        // beyond MAX_FRAME is rejected outright
        let n = buf.len();
        buf[n - 8..].copy_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        assert!(recv_frame(&mut buf.as_slice(), &meter).is_err());
    }

    #[test]
    fn frame_reader_takes_multiple_frames_from_one_feed() {
        let meter = Meter::default();
        let frames = [
            frame(FrameKind::Control, "hello", 3),
            frame(FrameKind::ModelDelta, "m.secB", 777),
            frame(FrameKind::Ack, "ack", 16),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            send_frame(&mut wire, f, &meter).unwrap();
        }
        let mut fr = FrameReader::new();
        fr.feed(&wire).unwrap();
        for f in &frames {
            let (got, _) = fr.next_frame().unwrap().expect("frame buffered");
            assert_eq!(&got, f);
        }
        assert!(fr.next_frame().unwrap().is_none());
        assert_eq!(fr.buffered(), 0);
    }

    #[test]
    fn frame_writer_matches_send_frame_bytes_and_meter() {
        let f = frame(FrameKind::ModelPart, "m.secA", 4_321);
        let blocking_meter = Meter::default();
        let mut blocking = Vec::new();
        send_frame(&mut blocking, &f, &blocking_meter).unwrap();

        let incremental_meter = Meter::default();
        let mut fw = FrameWriter::new();
        fw.queue(&f).unwrap();
        assert_eq!(fw.pending(), blocking.len());
        let mut sink = Vec::new();
        assert!(fw.flush_to(&mut sink, &incremental_meter).unwrap());
        assert!(fw.is_empty());
        assert_eq!(sink, blocking);
        assert_eq!(
            incremental_meter.snapshot().0,
            blocking_meter.snapshot().0,
            "metered exactly once, at frame completion"
        );
    }

    #[test]
    fn frame_reader_rejects_bad_prefix_eagerly() {
        let mut fr = FrameReader::new();
        // wrong magic is refused after only 4 bytes, not at frame end
        assert!(fr.feed(&[0xde, 0xad, 0xbe, 0xef]).is_err());
        let mut fr = FrameReader::new();
        fr.feed(&FRAME_MAGIC.to_le_bytes()).unwrap();
        assert!(fr.feed(&[99]).is_err(), "unknown kind refused at byte 5");
    }

    #[test]
    fn pull_times_out_on_dead_peer() {
        // A listener that accepts but never writes: the pull must return
        // an error within the timeout instead of hanging forever.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || listener.accept().map(|(s, _)| s));
        let meter = Meter::default();
        let t0 = std::time::Instant::now();
        let err = pull_frames_timeout(addr, 1, &meter, Some(Duration::from_millis(150)));
        assert!(err.is_err());
        assert!(t0.elapsed() < Duration::from_secs(10));
        drop(hold.join());
    }

    #[test]
    fn multiple_connections() {
        let frames = vec![frame(FrameKind::ModelFull, "m", 1_000)];
        let server = PushServer::serve_frames(frames.clone(), 3).unwrap();
        for _ in 0..3 {
            let m = Meter::default();
            let got = pull_frames(server.addr, 1, &m).unwrap();
            assert_eq!(got, frames);
        }
        let (sent, _) = server.join();
        assert!(sent >= 3_000);
    }
}
