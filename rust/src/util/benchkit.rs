//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `Bench::run` warms up, then executes timed iterations until a wall
//! budget is used, reporting min/mean/p50/p95 per iteration plus derived
//! throughput. Output is stable, grep-able `bench:` lines consumed by
//! EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// One benchmark runner.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

/// Result summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Summary {
    /// items/second given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 10_000,
        }
    }

    /// Per-case wall budget from `NQ_BENCH_BUDGET_MS` (CI caps the
    /// iteration budget this way), else [`Bench::quick`].
    pub fn from_env() -> Self {
        match std::env::var("NQ_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            Some(ms) => Bench {
                warmup: Duration::from_millis((ms / 5).clamp(10, 500)),
                budget: Duration::from_millis(ms.max(1)),
                ..Bench::default()
            },
            None => Bench::quick(),
        }
    }

    /// Time `f`; returns the summary and prints a `bench:` line.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Summary {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // timed
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let iters = samples.len();
        let sum: Duration = samples.iter().sum();
        let s = Summary {
            name: name.to_string(),
            iters,
            min: samples[0],
            mean: sum / iters as u32,
            p50: samples[iters / 2],
            p95: samples[(iters * 95 / 100).min(iters - 1)],
        };
        println!(
            "bench: {name:<44} {iters:>6} iters  mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}",
            s.mean, s.p50, s.p95, s.min
        );
        s
    }

    /// Run and also print a derived throughput line.
    pub fn run_throughput<F: FnMut()>(&self, name: &str, items: f64, unit: &str, f: F) -> Summary {
        let s = self.run(name, f);
        println!(
            "bench: {name:<44}        throughput {:>12.2} {unit}/s",
            s.throughput(items)
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
        };
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }
}
