//! CRC-64/XZ (aka CRC-64/GO-ECMA): the per-section integrity checksum
//! of the `.nq` trailer.
//!
//! Parameters (the widely deployed xz/liblzma variant): reflected
//! polynomial `0xC96C5795D7870F42`, init `!0`, xor-out `!0`, reflected
//! input/output. Table-driven, one 256-entry table built once per
//! process — fast enough to checksum section payloads at page-in
//! without showing up next to the decode kernels.
//!
//! The Python packer (`python/compile/nqformat.py`) implements the same
//! parameters, so trailers are cross-language stable.

use std::sync::OnceLock;

/// Reflected CRC-64/XZ polynomial.
const POLY: u64 = 0xC96C_5795_D787_0F42;

fn table() -> &'static [u64; 256] {
    static TABLE: OnceLock<[u64; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u64; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut crc = i as u64;
            for _ in 0..8 {
                crc = if crc & 1 == 1 {
                    (crc >> 1) ^ POLY
                } else {
                    crc >> 1
                };
            }
            *e = crc;
        }
        t
    })
}

/// CRC-64/XZ of `data`.
pub fn crc64(data: &[u8]) -> u64 {
    let t = table();
    let mut crc = !0u64;
    for &b in data {
        crc = t[((crc ^ b as u64) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical CRC-64/XZ check value
        assert_eq!(crc64(b"123456789"), 0x995D_C9BB_DF19_39FA);
        assert_eq!(crc64(b""), 0);
    }

    #[test]
    fn sensitive_to_any_flip() {
        let data: Vec<u8> = (0..=255).collect();
        let base = crc64(&data);
        for i in [0usize, 1, 100, 255] {
            let mut tampered = data.clone();
            tampered[i] ^= 0x40;
            assert_ne!(crc64(&tampered), base, "flip at {i}");
        }
    }
}
