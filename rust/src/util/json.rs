//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar we exchange with the Python build path:
//! `artifacts/manifest.json` and the `artifacts/report/*.json` files.
//! Integral tokens parse as i64 ([`Value::Int`]) so 64-bit counters
//! (telemetry bytes, snapshot fields) round-trip losslessly — an f64
//! detour corrupts above 2^53; everything else parses as f64. Objects
//! preserve insertion order so report rendering is deterministic. The
//! writer never emits bare `NaN`/`inf` (not JSON): non-finite f64
//! serializes as `null`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    /// Integral number token (no `.`/`e`): kept as i64 so u64-scale
    /// counters survive a snapshot round-trip bit-exactly.
    Int(i64),
    Str(String),
    Array(Vec<Value>),
    /// Insertion-ordered key/value pairs.
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` chained through several keys, with a useful error.
    pub fn path(&self, keys: &[&str]) -> Result<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur
                .get(k)
                .ok_or_else(|| anyhow!("missing key {:?} in path {:?}", k, keys))?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// Lossless u64: [`Value::Int`] converts exactly (negatives are an
    /// error, not 0); an f64 is accepted only when it is integral and
    /// within the exactly-representable range (< 2^53) — silently
    /// truncating 18446744073709551615.0 was how snapshot counters
    /// corrupted.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) => u64::try_from(*i)
                .map_err(|_| anyhow!("expected unsigned integer, got {i}")),
            Value::Num(n) => {
                if n.fract() == 0.0 && *n >= 0.0 && *n < 9_007_199_254_740_992.0 {
                    Ok(*n as u64)
                } else {
                    bail!("expected exact unsigned integer, got {n}")
                }
            }
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// Lossless i64 (same contract as [`as_u64`](Self::as_u64)).
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9_007_199_254_740_992.0 {
                    Ok(*n as i64)
                } else {
                    bail!("expected exact integer, got {n}")
                }
            }
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(usize::try_from(self.as_u64()?)?)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_object(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Object(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object keys → values as a map (convenience for lookups).
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Value>> {
        Ok(self
            .as_object()?
            .iter()
            .map(|(k, v)| (k.as_str(), v))
            .collect())
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

/// Parse a complete JSON document.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing bytes at offset {}", p.i);
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            out.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad \\u"))?);
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // raw UTF-8 byte: copy the full code point
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        let mut integral = true;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            if matches!(self.b[self.i], b'.' | b'e' | b'E') {
                integral = false;
            }
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        // integral tokens stay exact; i64 overflow falls back to f64
        if integral {
            if let Ok(i) = s.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        Ok(Value::Num(s.parse::<f64>().context("bad number")?))
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Array(items));
                }
                c => bail!("expected , or ] at {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Object(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Object(kv));
                }
                c => bail!("expected , or }} at {}, found {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if !n.is_finite() {
                // bare NaN/inf is not JSON — a reader would reject the
                // whole artifact, so degrade the one value instead
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Int(i) => {
            let _ = write!(out, "{i}");
        }
        Value::Str(s) => write_str(out, s),
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Object(kv) => {
            out.push('{');
            for (i, (k, val)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for constructing report JSON.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn int(i: i64) -> Value {
    Value::Int(i)
}

/// Lossless u64 builder for counters. Values past i64::MAX (never seen
/// from real counters) degrade to f64 rather than failing the write.
pub fn uint(u: u64) -> Value {
    match i64::try_from(u) {
        Ok(i) => Value::Int(i),
        Err(_) => Value::Num(u as f64),
    }
}

pub fn bool_(b: bool) -> Value {
    Value::Bool(b)
}

pub fn str_(s: impl Into<String>) -> Value {
    Value::Str(s.into())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.as_object().unwrap()[0].1.as_array().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert!(!v.get("c").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀 é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s\n",{"y":null,"z":true}],"n":-3}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn preserves_object_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn u64_counters_roundtrip_losslessly() {
        // 2^53 + 1 is where the old f64 detour started corrupting
        for u in [0u64, 1, 9_007_199_254_740_993, u64::MAX / 2, i64::MAX as u64] {
            let v = parse(&to_string(&uint(u))).unwrap();
            assert_eq!(v.as_u64().unwrap(), u, "u={u}");
        }
        assert_eq!(parse("9007199254740993").unwrap().as_u64().unwrap(), 9_007_199_254_740_993);
        assert_eq!(parse("-5").unwrap().as_i64().unwrap(), -5);
        // negatives are an error, not 0 (the old cast mapped them to 0)
        assert!(parse("-5").unwrap().as_u64().is_err());
        // non-integral f64s don't silently truncate
        assert!(Value::Num(1.5).as_u64().is_err());
        // integral f64 in the exact range still converts (legacy artifacts)
        assert_eq!(Value::Num(42.0).as_u64().unwrap(), 42);
        assert_eq!(Value::Num(42.0).as_usize().unwrap(), 42);
        // past 2^53 an f64 is no longer exact — reject instead of guessing
        assert!(Value::Num(2f64.powi(60)).as_u64().is_err());
    }

    #[test]
    fn non_finite_writes_null_not_bare_nan() {
        let v = obj(vec![
            ("ok", num(1.5)),
            ("nan", num(f64::NAN)),
            ("inf", num(f64::INFINITY)),
            ("ninf", num(f64::NEG_INFINITY)),
        ]);
        let s = to_string(&v);
        assert_eq!(s, r#"{"ok":1.5,"nan":null,"inf":null,"ninf":null}"#);
        // and the output is valid JSON again
        let back = parse(&s).unwrap();
        assert!(back.get("nan").unwrap().is_null());
    }

    #[test]
    fn int_tokens_parse_exact_and_overflow_falls_back() {
        assert_eq!(parse("7").unwrap(), Value::Int(7));
        assert_eq!(parse(&i64::MIN.to_string()).unwrap(), Value::Int(i64::MIN));
        // past i64: still parses (as f64), never errors
        let big = parse("99999999999999999999999999").unwrap();
        assert!(matches!(big, Value::Num(_)));
        // fractional and exponent forms stay f64
        assert_eq!(parse("2e3").unwrap(), Value::Num(2000.0));
    }
}
