//! Pure-std substrates replacing unavailable crates (DESIGN.md §2):
//! JSON, PRNG, property testing, thread pool, and small I/O helpers.

pub mod benchkit;
pub mod crc64;
pub mod json;
pub mod pool;
pub mod prng;
pub mod propcheck;

use anyhow::{Context, Result};
use std::path::Path;

/// Read a little-endian f32 binary blob (the artifacts' raw tensor format).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian u32 binary blob (labels).
pub fn read_u32_file(path: &Path) -> Result<Vec<u32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Human-readable byte size (MB with paper-style 1e6 divisor).
pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_file_roundtrip() {
        let dir = std::env::temp_dir().join("nq_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.f32");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), vals);
    }

    #[test]
    fn mb_uses_1e6() {
        assert!((mb(44_700_000) - 44.7).abs() < 1e-9);
    }
}
