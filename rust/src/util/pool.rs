//! Fixed-size thread pool (tokio is unavailable offline).
//!
//! The coordinator uses this for request handling and the transport
//! server for per-connection workers. Scoped-task semantics are provided
//! via `scope_map` for data-parallel work in the quantizer and benches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("nq-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped → shutdown
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            queued,
        }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool send");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to each item on `threads` OS threads and collect results in
/// input order. Panics in `f` propagate.
pub fn scope_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let items: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(item);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Default worker count: physical parallelism minus one, at least 1.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_pending_drains() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.execute(|| thread::sleep(Duration::from_millis(1)));
        }
        while pool.pending() > 0 {
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn scope_map_preserves_order() {
        let out = scope_map((0..1000).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let out: Vec<i32> = scope_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
