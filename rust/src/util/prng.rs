//! Deterministic PRNG (splitmix64 + xoshiro256**) for tests, benches and
//! the device simulator. `rand` is unavailable offline; this is the
//! standard public-domain construction.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_in_range_inclusive() {
        let mut r = Rng::new(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
