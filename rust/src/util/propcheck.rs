//! Mini property-testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it performs a bounded greedy shrink (the generator
//! receives a shrink "scale" in [0,1] so smaller inputs can be resampled)
//! and panics with the seed + smallest failing input debug-print, so a
//! failure is reproducible by seed.

use super::prng::Rng;

/// Run a property over `cases` random inputs.
///
/// `gen(rng, scale)` produces an input; `scale` starts at 1.0 and is
/// reduced while shrinking, so generators should produce "smaller" values
/// for smaller scales (fewer elements, narrower ranges).
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, f64) -> T,
    P: FnMut(&T) -> bool,
{
    // Deterministic per-property seed from the name, stable across runs.
    let seed = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        let input = gen(&mut rng, 1.0);
        if prop(&input) {
            continue;
        }
        // Shrink: resample at decreasing scales from the same stream seed.
        let mut smallest = input;
        for step in 1..=16 {
            let scale = 1.0 - step as f64 / 17.0;
            let mut srng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B9) ^ step);
            let cand = gen(&mut srng, scale);
            if !prop(&cand) {
                smallest = cand;
            }
        }
        panic!(
            "property {name:?} failed (case {case}, seed {seed:#x}).\n\
             smallest failing input:\n{smallest:#?}"
        );
    }
}

/// Generator helper: vector of i64 in [lo, hi], length scaled.
pub fn vec_i64(rng: &mut Rng, scale: f64, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
    let len = ((max_len as f64 * scale) as usize).max(1);
    let len = rng.index(len) + 1;
    (0..len).map(|_| rng.int(lo, hi)).collect()
}

/// Generator helper: vector of f64 normals, length scaled.
pub fn vec_f64(rng: &mut Rng, scale: f64, max_len: usize) -> Vec<f64> {
    let len = ((max_len as f64 * scale) as usize).max(2);
    let len = rng.index(len).max(1) + 1;
    (0..len).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 200, |r, _| (r.int(-100, 100), r.int(-100, 100)),
              |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_input() {
        check("always-false", 5, |r, s| vec_i64(r, s, 100, -10, 10), |_| false);
    }

    #[test]
    fn deterministic_across_runs() {
        // Same name → same seed → same first input.
        let mut first: Option<Vec<i64>> = None;
        for _ in 0..2 {
            let mut captured = None;
            check("capture", 1, |r, s| vec_i64(r, s, 50, 0, 9), |v| {
                captured = Some(v.clone());
                true
            });
            match &first {
                None => first = captured,
                Some(f) => assert_eq!(f, &captured.unwrap()),
            }
        }
    }
}
