//! Property tests for the dynamic batcher (`util::propcheck`): over
//! randomized arrival patterns and a (batch_size, max_wait) grid,
//!
//! * partial batches are zero-padded to the exact compiled shape,
//! * request order is preserved across consecutive batches,
//! * no batch exceeds `batch_size`, and
//! * the oldest member's wait is bounded by `max_wait` + scheduling ε
//!   (the deadline anchors at enqueue time — a backlogged request can
//!   never be double-waited).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use nestquant::coordinator::batcher::{next_batch, BatcherConfig, Reply, Request};
use nestquant::util::propcheck;

fn req(tag: f32, image_len: usize, replies: &mut Vec<mpsc::Receiver<Reply>>) -> Request {
    let (tx, rx) = mpsc::channel();
    replies.push(rx);
    Request {
        image: vec![tag; image_len],
        reply: tx,
        enqueued: Instant::now(),
    }
}

/// Deterministic half: pre-filled queues over a randomized
/// (batch_size, image_len, request count) grid. Shape, padding, order,
/// and conservation hold for every draw.
#[test]
fn prop_batches_are_exact_shape_ordered_and_zero_padded() {
    propcheck::check(
        "batcher-shape-order-padding",
        60,
        |rng, scale| {
            let batch_size = 1 + rng.index(6);
            let image_len = 1 + rng.index(16);
            let count = rng.index(((40.0 * scale) as usize).max(2));
            (batch_size, image_len, count)
        },
        |&(batch_size, image_len, count)| {
            let cfg = BatcherConfig {
                batch_size,
                image_len,
                // pre-filled queue: full batches close immediately, the
                // final partial one closes on this timeout
                max_wait: Duration::from_millis(5),
            };
            let (tx, rx) = mpsc::channel();
            let mut replies = Vec::new();
            for i in 0..count {
                tx.send(req(i as f32 + 1.0, image_len, &mut replies)).unwrap();
            }
            drop(tx);
            let mut next_tag = 1.0f32;
            let mut seen = 0usize;
            while let Some(b) = next_batch(&rx, &cfg) {
                // exact compiled shape, never exceeded
                if b.input.len() != batch_size * image_len {
                    return false;
                }
                if b.requests.is_empty() || b.requests.len() > batch_size {
                    return false;
                }
                // order preserved: tags are consecutive across batches,
                // and each row of the input holds its request's image
                for (i, r) in b.requests.iter().enumerate() {
                    if r.image[0] != next_tag {
                        return false;
                    }
                    let row = &b.input[i * image_len..(i + 1) * image_len];
                    if row != vec![next_tag; image_len].as_slice() {
                        return false;
                    }
                    next_tag += 1.0;
                }
                // padding rows are all zero
                let pad = &b.input[b.requests.len() * image_len..];
                if pad.iter().any(|&v| v != 0.0) {
                    return false;
                }
                seen += b.requests.len();
            }
            seen == count // conservation: every request batched once
        },
    );
}

/// Timed half: a producer with randomized inter-arrival delays. Every
/// batch's `oldest_wait` stays within `max_wait` plus a generous
/// scheduling ε, across the (batch_size, max_wait) grid.
#[test]
fn prop_oldest_wait_bounded_under_randomized_arrivals() {
    const EPSILON: Duration = Duration::from_millis(250);
    propcheck::check(
        "batcher-oldest-wait",
        6,
        |rng, scale| {
            let batch_size = 1 + rng.index(4);
            let max_wait_ms = 15 + rng.index(25) as u64;
            let n = 1 + rng.index(((10.0 * scale) as usize).max(1));
            let delays: Vec<u64> = (0..n).map(|_| rng.index(15) as u64).collect();
            (batch_size, max_wait_ms, delays)
        },
        |&(batch_size, max_wait_ms, ref delays)| {
            let cfg = BatcherConfig {
                batch_size,
                image_len: 4,
                max_wait: Duration::from_millis(max_wait_ms),
            };
            let (tx, rx) = mpsc::channel();
            let delays = delays.clone();
            let producer = std::thread::spawn(move || {
                let mut replies = Vec::new();
                for (i, d) in delays.iter().enumerate() {
                    std::thread::sleep(Duration::from_millis(*d));
                    tx.send(req(i as f32, 4, &mut replies)).unwrap();
                }
                replies
            });
            let mut ok = true;
            while let Some(b) = next_batch(&rx, &cfg) {
                if b.oldest_wait > cfg.max_wait + EPSILON {
                    eprintln!(
                        "oldest_wait {:?} > max_wait {:?} + ε",
                        b.oldest_wait, cfg.max_wait
                    );
                    ok = false;
                }
            }
            drop(producer.join().unwrap());
            ok
        },
    );
}
