//! Chaos: deterministic fault schedules against live servers, proving
//! graceful degradation end to end.
//!
//! What is proven here:
//!
//! 1. **Panic isolation**: an injected `worker.job` panic is contained
//!    by the batch-level `catch_unwind` — the client gets a typed error
//!    naming the panic, the same connection keeps working, and results
//!    after the panic are byte-identical to before it.
//! 2. **Admission control**: with every worker wedged and the infer
//!    queue at its depth cap, further requests get a typed `busy`
//!    refusal, and refusals equal the shed-counter delta exactly.
//! 3. **Circuit breaking**: consecutive executor failures open the
//!    per-tenant breaker (refusals without touching the executor), the
//!    cooldown half-opens it, one successful probe closes it.
//! 4. **The seeded storm**: an `NQ_FAULTS`-grammar schedule against a
//!    live coordinator plus a deterministic mid-transfer abort on a
//!    live fleet server. Every request ends in a reply or a typed
//!    error, byte accounting stays exact, the thread population stays
//!    bounded (panicked workers respawn in place), and once faults
//!    clear the same requests return byte-identical results.
//! 5. **Wire robustness**: mid-frame EOF and garbage frames close only
//!    the offending connection; truncated artifacts yield typed errors.
//!
//! Failpoints are process-global, so every test here serializes behind
//! one mutex and brackets itself with `faults::clear()`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;
use nestquant::container;
use nestquant::coordinator::server::{
    serve_tenants, Client, ServerConfig, ServerHandle, TenantExecutor,
};
use nestquant::coordinator::{Decision, SwitchCost, Variant};
use nestquant::faults::{self, FaultMode, FaultSpec};
use nestquant::fleet::{FleetConfig, FleetServer, RemoteSource, Zoo};
use nestquant::store::{FileSource, MmapSource, NqArchive, SectionSource, StoreBudget};
use nestquant::telemetry::registry;

const TIMEOUT: Duration = Duration::from_secs(30);
const IMAGE_LEN: usize = 16;
const CLASSES: usize = 4;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_chaos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A deterministic request image: fault-free logits for it are
/// byte-reproducible across runs.
fn image(k: usize) -> Vec<f32> {
    (0..IMAGE_LEN)
        .map(|i| ((i * 7 + k * 13) % 31) as f32 * 0.125)
        .collect()
}

/// Live thread count of this process (`/proc/self/task`); elsewhere 0,
/// degrading the bound check to trivially true.
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

fn archive(seed: u64) -> Arc<NqArchive> {
    let c = container::synthetic_nest(seed, 8, 4, 64, 8).unwrap();
    Arc::new(NqArchive::from_container(&c).unwrap())
}

/// Knobs into one hosted [`SyntheticTenant`]: (fail, gate, batches).
type Knobs = (Arc<AtomicBool>, Arc<AtomicBool>, Arc<AtomicU64>);

/// Deterministic, dependency-free tenant: logits are a fixed function
/// of the input, so fault-free replies are byte-reproducible. `gate`
/// wedges batches (overload tests); `fail` makes them error (breaker
/// tests); `batches` counts executor entries.
struct SyntheticTenant {
    variant: Variant,
    fail: Arc<AtomicBool>,
    gate: Arc<AtomicBool>,
    batches: Arc<AtomicU64>,
}

impl SyntheticTenant {
    fn new() -> SyntheticTenant {
        SyntheticTenant {
            variant: Variant::PartBit,
            fail: Arc::new(AtomicBool::new(false)),
            gate: Arc::new(AtomicBool::new(false)),
            batches: Arc::new(AtomicU64::new(0)),
        }
    }
}

impl TenantExecutor for SyntheticTenant {
    fn shape(&self) -> (usize, usize, usize) {
        (1, IMAGE_LEN, CLASSES)
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.batches.fetch_add(1, Ordering::SeqCst);
        while self.gate.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        if self.fail.load(Ordering::SeqCst) {
            anyhow::bail!("synthetic executor failure");
        }
        let sum: f32 = input.iter().sum();
        Ok((0..CLASSES)
            .map(|c| sum * (c as f32 + 1.0) + input[c])
            .collect())
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        if let Decision::SwitchTo(v) = decision {
            self.variant = v;
        }
        Ok(None)
    }

    fn variant(&self) -> Variant {
        self.variant
    }
}

fn serve_synthetic(ids: &[&str], config: ServerConfig) -> (ServerHandle, Vec<Knobs>) {
    let mut tenants = Vec::new();
    let mut knobs = Vec::new();
    for id in ids {
        let t = SyntheticTenant::new();
        knobs.push((
            Arc::clone(&t.fail),
            Arc::clone(&t.gate),
            Arc::clone(&t.batches),
        ));
        tenants.push((id.to_string(), Box::new(t) as Box<dyn TenantExecutor>));
    }
    let handle = serve_tenants(tenants, config).unwrap();
    (handle, knobs)
}

/// An injected `worker.job` panic is contained by the batch-level
/// `catch_unwind`: typed error out, tenant stays live, results after
/// the panic are byte-identical to before it.
#[test]
fn worker_panic_is_isolated_and_tenant_stays_live() {
    let _g = serial();
    faults::clear();
    let panics0 = registry().faults.worker_panics.get();
    let (handle, _) = serve_synthetic(
        &["m0"],
        ServerConfig {
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    );
    let mut client = Client::connect(handle.addr).unwrap();
    let img = image(0);
    let baseline = client.infer_model("m0", &img).unwrap();
    assert_eq!(baseline.len(), CLASSES);

    faults::arm("worker.job", FaultSpec::always(FaultMode::Panic).times(1));
    let err = client.infer_model("m0", &img).unwrap_err();
    assert!(
        format!("{err:#}").contains("panicked"),
        "typed panic reply, got: {err:#}"
    );
    assert_eq!(
        registry().faults.worker_panics.get() - panics0,
        1,
        "exactly one contained panic"
    );
    // same connection, same tenant, same bytes: nothing leaked
    assert_eq!(client.infer_model("m0", &img).unwrap(), baseline);
    faults::clear();
    handle.stop();
}

/// Queue-depth admission control under a real overload: refusals are
/// typed `busy` replies and equal the shed-counter delta exactly.
#[test]
fn overload_sheds_with_typed_busy_and_exact_accounting() {
    let _g = serial();
    faults::clear();
    const CLIENTS: usize = 48;
    let shed0 = registry().faults.shed_total.get();
    let (handle, knobs) = serve_synthetic(
        &["m0"],
        ServerConfig {
            max_wait: Duration::from_micros(100),
            infer_queue_cap: 1,
            ..ServerConfig::default()
        },
    );
    let (_, gate, _) = &knobs[0];
    gate.store(true, Ordering::SeqCst);

    let addr = handle.addr;
    let joins: Vec<_> = (0..CLIENTS)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                match c.infer_model("m0", &image(k)) {
                    Ok(v) => {
                        assert_eq!(v.len(), CLASSES);
                        true
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(msg.contains("busy"), "only typed busy refusals: {msg}");
                        assert!(msg.contains("queue full"), "{msg}");
                        false
                    }
                }
            })
        })
        .collect();

    // overload is observable before anything completes: wait for the
    // first shed (worker count < CLIENTS, so one must occur), then
    // unblock the wedged workers
    let t0 = Instant::now();
    while registry().faults.shed_total.get() == shed0 {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "no shed under overload"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    gate.store(false, Ordering::SeqCst);

    let results: Vec<bool> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = results.iter().filter(|r| **r).count() as u64;
    let busy = results.len() as u64 - ok;
    assert!(ok >= 1, "queued and in-flight jobs still complete");
    assert!(busy >= 1);
    assert_eq!(
        registry().faults.shed_total.get() - shed0,
        busy,
        "every busy reply is one shed, counted exactly"
    );
    handle.stop();
}

/// The per-tenant circuit breaker: consecutive executor failures open
/// it (typed `busy` without touching the executor), the cooldown
/// half-opens it, and one successful probe closes it again.
#[test]
fn circuit_breaker_opens_and_recovers_after_cooldown() {
    let _g = serial();
    faults::clear();
    let (handle, knobs) = serve_synthetic(
        &["m0"],
        ServerConfig {
            max_wait: Duration::from_millis(1),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            ..ServerConfig::default()
        },
    );
    let (fail, _, batches) = &knobs[0];
    let mut client = Client::connect(handle.addr).unwrap();
    let img = image(1);

    fail.store(true, Ordering::SeqCst);
    for _ in 0..3 {
        let err = client.infer_model("m0", &img).unwrap_err();
        assert!(format!("{err:#}").contains("server error"), "{err:#}");
    }
    // threshold reached: the breaker now refuses BEFORE the executor
    let ran = batches.load(Ordering::SeqCst);
    let msg = format!("{:#}", client.infer_model("m0", &img).unwrap_err());
    assert!(msg.contains("busy") && msg.contains("circuit open"), "{msg}");
    assert_eq!(
        batches.load(Ordering::SeqCst),
        ran,
        "an open breaker never reaches the executor"
    );

    // cooldown elapses; the half-open probe succeeds and closes it
    fail.store(false, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(400));
    let out = client.infer_model("m0", &img).unwrap();
    assert_eq!(out.len(), CLASSES);
    assert_eq!(
        client.infer_model("m0", &img).unwrap(),
        out,
        "steady state restored"
    );
    handle.stop();
}

/// The headline storm: a seeded `NQ_FAULTS`-grammar schedule (worker
/// panics + wire delays) against a live coordinator, plus a
/// deterministic mid-transfer abort on a live fleet server.
#[test]
fn seeded_chaos_schedule_degrades_gracefully_and_recovers() {
    let _g = serial();
    faults::clear();
    const ROUNDS: usize = 20;
    const CHUNK: usize = 256;

    let (handle, _) = serve_synthetic(
        &["m0", "m1"],
        ServerConfig {
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    );
    let dir = temp_dir("storm");
    let c = container::synthetic_nest(43, 8, 4, 128, 16).unwrap();
    let (_, a_len, _) = container::write(&dir.join("m0.nq"), &c).unwrap();
    assert!(
        a_len > 3 * CHUNK as u64,
        "section A must outlast the injected abort"
    );
    let mut zoo = Zoo::new();
    zoo.add("m0", dir.join("m0.nq"));
    let fleet = FleetServer::start(
        zoo,
        FleetConfig {
            chunk_bytes: CHUNK,
            ..FleetConfig::default()
        },
    )
    .unwrap();

    // fault-free baseline over a fixed request set
    let mut client = Client::connect(handle.addr).unwrap();
    let imgs: Vec<Vec<f32>> = (0..4).map(image).collect();
    let mut baseline = Vec::new();
    for id in ["m0", "m1"] {
        for img in &imgs {
            baseline.push(client.infer_model(id, img).unwrap());
        }
    }

    let threads0 = thread_count();

    // the documented NQ_FAULTS grammar, armed through the same parser.
    // Seed 101 is pinned: over these 160 batch checks it fires some
    // panics and spares most, never 5 in a row (the breaker threshold).
    faults::arm_from_str("worker.job=panic:0.08@101;transport.send=delay_ms:1").unwrap();
    let (mut ok, mut errs) = (0u64, 0u64);
    for _ in 0..ROUNDS {
        for id in ["m0", "m1"] {
            for img in &imgs {
                match client.infer_model(id, img) {
                    Ok(v) => {
                        assert_eq!(v.len(), CLASSES);
                        ok += 1;
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(
                            msg.contains("server error")
                                || msg.contains("server busy")
                                || msg.contains("injected"),
                            "typed failures only: {msg}"
                        );
                        errs += 1;
                    }
                }
            }
        }
    }
    assert_eq!(
        ok + errs,
        (ROUNDS * 2 * imgs.len()) as u64,
        "no request vanished"
    );
    assert!(ok > 0 && errs > 0, "seed 101 fires and spares (ok={ok} errs={errs})");
    assert!(faults::fired("worker.job") >= 1, "the schedule is scrapeable");

    // fleet under chaos: the server aborts the transfer at the third
    // chunk; the client backs off, reconnects, resumes from the acked
    // offset, and the reassembled section is complete and exact.
    let reg = registry();
    let resumed0 = reg.fleet.resumed_bytes.get();
    let restarted0 = reg.fleet.restarted_bytes.get();
    faults::arm("fleet.chunk", FaultSpec::always(FaultMode::Err).after(2).times(1));
    let remote = RemoteSource::connect(fleet.addr, "dev-chaos", "m0", TIMEOUT).unwrap();
    let arch = NqArchive::with_source(Arc::new(remote)).unwrap();
    arch.part_bit().unwrap();
    let s = arch.stats();
    assert_eq!(s.a_fetches, 1, "one logical fetch despite the abort");
    assert_eq!(s.a_bytes_fetched, a_len, "byte accounting exact under faults");
    assert_eq!(faults::fired("fleet.chunk"), 1);
    let resumed = reg.fleet.resumed_bytes.get() - resumed0;
    let restarted = reg.fleet.restarted_bytes.get() - restarted0;
    assert_eq!(
        resumed + restarted,
        2 * CHUNK as u64,
        "the aborted attempt had acked exactly 2 chunks"
    );
    assert!(resumed > 0, "resume keeps acked bytes, not restart from zero");

    // panicked workers respawned in place: thread population is flat
    let threads1 = thread_count();
    assert!(
        threads1 <= threads0 + 2,
        "thread population bounded: {threads0} -> {threads1}"
    );

    // faults off: the exact same requests are byte-identical to the
    // fault-free baseline — degradation left no residue
    faults::clear();
    let mut after = Vec::new();
    for id in ["m0", "m1"] {
        for img in &imgs {
            after.push(client.infer_model(id, img).unwrap());
        }
    }
    assert_eq!(after, baseline, "byte-identical once faults clear");
    fleet.stop();
    handle.stop();
}

/// Wire robustness: a connection that dies mid-frame (or talks garbage)
/// is closed alone — the server neither panics nor takes healthy
/// connections down with it.
#[test]
fn mid_frame_eof_closes_only_the_offending_connection() {
    let _g = serial();
    faults::clear();
    let (handle, _) = serve_synthetic(
        &["m0"],
        ServerConfig {
            max_wait: Duration::from_millis(1),
            ..ServerConfig::default()
        },
    );
    let mut good = Client::connect(handle.addr).unwrap();
    let img = image(2);
    let baseline = good.infer_model("m0", &img).unwrap();

    // half a frame header, then EOF ("NQTX" magic + Control kind + a
    // dangling name-length byte)
    let mut torn = TcpStream::connect(handle.addr).unwrap();
    torn.write_all(&[0x58, 0x54, 0x51, 0x4E, 4, 5]).unwrap();
    drop(torn);

    // outright garbage: the server must reject and close this conn
    let mut garbage = TcpStream::connect(handle.addr).unwrap();
    garbage
        .write_all(&[0xde, 0xad, 0xbe, 0xef, 0xff, 0xff, 0xff, 0xff])
        .unwrap();
    garbage.set_read_timeout(Some(TIMEOUT)).unwrap();
    let mut buf = [0u8; 64];
    // the peer either closes cleanly (EOF) or resets; both are fine —
    // what matters is that it answers instead of wedging
    let _ = garbage.read(&mut buf);

    // the healthy connection and a brand-new one are untouched
    assert_eq!(good.infer_model("m0", &img).unwrap(), baseline);
    let mut fresh = Client::connect(handle.addr).unwrap();
    assert_eq!(fresh.infer_model("m0", &img).unwrap(), baseline);
    handle.stop();
}

/// A `.nq` artifact truncated mid-section (trailer gone, section B cut
/// short) yields a typed, descriptive error — never a panic, never
/// silently-short bytes.
#[test]
fn truncated_artifact_yields_typed_error_not_panic() {
    let _g = serial();
    faults::clear();
    let dir = temp_dir("trunc");
    let path = dir.join("m0.nq");
    let c = container::synthetic_nest(47, 8, 4, 64, 8).unwrap();
    container::write(&path, &c).unwrap();
    let idx = FileSource::new(&path).index().unwrap();
    let b = idx.section_b();
    let keep = (b.start + (b.end - b.start) / 2) as usize;
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..keep]).unwrap();

    let outcome = (|| -> Result<()> {
        let src: Arc<dyn SectionSource> = Arc::new(FileSource::new(&path));
        let arch = NqArchive::with_source(src)?;
        arch.part_bit()?; // section A is intact
        arch.attach_b()?; // section B is cut short
        Ok(())
    })();
    let msg = format!("{:#}", outcome.unwrap_err());
    assert!(
        msg.contains("section B") || msg.contains("reading") || msg.contains("truncated"),
        "typed + descriptive: {msg}"
    );
}

/// An injected eviction failure aborts the attach atomically: the
/// ledger still balances, the resident set is untouched, and the same
/// attach succeeds once the fault clears.
#[test]
fn injected_evict_failure_keeps_budget_ledger_exact() {
    let _g = serial();
    faults::clear();
    let a0 = archive(0xB0B0);
    let a1 = archive(0xB0B1);
    let b_len = a0.section_b_bytes();
    let budget = StoreBudget::new(b_len); // room for exactly one tenant
    budget.attach_b("m0", &a0).unwrap();
    assert_eq!(budget.resident_bytes(), b_len);

    faults::arm("store.evict", FaultSpec::always(FaultMode::Err).times(1));
    let err = budget.attach_b("m1", &a1).unwrap_err();
    assert!(format!("{err:#}").contains("evicting"), "{err:#}");
    assert_eq!(budget.resident_bytes(), b_len, "failed attach moved no bytes");
    assert!(budget.is_resident("m0") && !budget.is_resident("m1"));
    let evictions0 = budget.evictions();

    faults::clear();
    let evicted = budget.attach_b("m1", &a1).unwrap();
    assert_eq!(evicted, vec!["m0".to_string()]);
    assert_eq!(budget.resident_bytes(), b_len, "ledger exact after recovery");
    assert_eq!(budget.evictions(), evictions0 + 1);
    // in-memory sources yield owned bytes: the whole ledger is on the
    // owned side, and the mapped side never went negative-by-proxy
    assert_eq!(budget.owned_bytes(), b_len, "owned side carries the ledger");
    assert_eq!(budget.mapped_bytes(), 0, "no mmap windows from MemorySource");
}

/// Lazy CRC with an injected `store.crc` failure: the first touch fails
/// and the verdict is **memoized** — the section keeps failing after
/// the fault clears (no silent self-heal on a corrupt read), the
/// failure counter ticks exactly once, and the untouched section's
/// verdict is independent and clean.
#[test]
fn injected_crc_failure_memoizes_verdict_per_section() {
    let _g = serial();
    faults::clear();
    let arch = archive(0xC4C0);
    let crc0 = registry().store.crc_failures.get();

    // fires on the first hash only; section B's later first touch
    // consults an exhausted spec and verifies for real
    faults::arm("store.crc", FaultSpec::always(FaultMode::Err).times(1));
    let err = format!("{:#}", arch.ensure_a().unwrap_err());
    assert!(err.contains("section A checksum mismatch"), "{err}");
    assert_eq!(registry().store.crc_failures.get() - crc0, 1);

    faults::clear();
    // memoized: still failing, but WITHOUT re-hashing or re-counting
    let err2 = format!("{:#}", arch.ensure_a().unwrap_err());
    assert!(err2.contains("section A checksum mismatch"), "{err2}");
    assert_eq!(
        registry().store.crc_failures.get() - crc0,
        1,
        "memoized failure re-bails without re-counting"
    );

    // section B's verdict is its own: it verifies and attaches cleanly
    let b = arch.attach_b().unwrap();
    assert_eq!(b.len() as u64, arch.section_b_bytes());
    let s = arch.stats();
    assert_eq!(s.a_fetches, 0, "a failed A never counts as fetched");
    assert_eq!(s.b_fetches, 1);
}

/// `store.map` failpoint: an injected mmap failure degrades the source
/// to positioned reads — same bytes, owned instead of mapped, one
/// `map_faults` tick — and the degraded verdict is memoized (no
/// remap attempt per fetch).
#[cfg(all(unix, feature = "mmap"))]
#[test]
fn injected_map_failure_degrades_to_positioned_reads() {
    use nestquant::store::Section;

    let _g = serial();
    faults::clear();
    let dir = temp_dir("mapfault");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(0x3A90, 8, 4, 64, 8).unwrap();
    container::write(&path, &c).unwrap();
    let faults0 = registry().store.map_faults.get();

    faults::arm("store.map", FaultSpec::always(FaultMode::Err).times(1));
    let src = MmapSource::new(&path);
    let a = src.fetch(Section::A).unwrap();
    assert!(!a.is_mapped(), "degraded fetch must be owned bytes");
    assert_eq!(registry().store.map_faults.get() - faults0, 1);

    faults::clear();
    // the degrade verdict is memoized: no second map attempt, still
    // serving owned bytes, and they are byte-identical to a FileSource
    let b = src.fetch(Section::B).unwrap();
    assert!(!b.is_mapped());
    assert_eq!(
        registry().store.map_faults.get() - faults0,
        1,
        "one fault recorded for the source's single map attempt"
    );
    let file = FileSource::new(&path);
    assert_eq!(&a[..], &file.fetch(Section::A).unwrap()[..]);
    assert_eq!(&b[..], &file.fetch(Section::B).unwrap()[..]);
}
