//! Connection-storm test for the reactor-based fleet server: thousands
//! of loopback devices connect, hello, trade advice, and drop — while
//! the server's OS thread count stays bounded by its worker-pool size
//! (sessions are state, not threads) and every advice reply matches a
//! client-side replay of the hysteresis policy.
//!
//! Linux-only: it raises `RLIMIT_NOFILE` and counts threads through
//! `/proc/self/task`. The connection target adapts to the file-
//! descriptor budget actually granted (each loopback connection costs
//! two descriptors in-process), so a capped sandbox still exercises the
//! storm at reduced scale.

#![cfg(target_os = "linux")]

use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use nestquant::coordinator::{PolicyState, SwitchPolicy, Variant};
use nestquant::fleet::{FleetConfig, FleetServer, RateLimit, Zoo};
use nestquant::reactor::raise_nofile_limit;
use nestquant::telemetry::registry;
use nestquant::transport::{recv_frame, send_frame, Frame, FrameKind, Meter};

const CLIENT_THREADS: usize = 16;

/// Both tests assert over the process-global telemetry registry, so
/// they must not interleave.
static SERIAL: Mutex<()> = Mutex::new(());

fn control(name: &str, payload: Vec<u8>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: name.into(),
        payload,
    }
}

/// Server threads alive right now (reactor loop + workers), identified
/// by the `nq-` prefix every server-side thread name carries.
fn server_thread_count() -> usize {
    std::fs::read_dir("/proc/self/task")
        .unwrap()
        .filter_map(|e| std::fs::read_to_string(e.unwrap().path().join("comm")).ok())
        .filter(|comm| comm.starts_with("nq-"))
        .count()
}

/// Connect with retries: under a storm the accept backlog can overflow
/// transiently, which is exactly the condition being exercised.
fn connect(addr: SocketAddr) -> TcpStream {
    let mut delay = Duration::from_millis(1);
    for _ in 0..60 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                return s;
            }
            Err(_) => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(100));
            }
        }
    }
    panic!("could not connect to {addr} after 60 attempts");
}

fn hello(sock: &mut TcpStream, device: &str, meter: &Meter) {
    send_frame(sock, &control("hello", device.as_bytes().to_vec()), meter).unwrap();
    let (reply, _) = recv_frame(sock, meter).unwrap();
    assert_eq!(reply.name, "ok", "hello({device}) got {:?}", reply.name);
}

/// Drive `n` advice round-trips over one connection, asserting each
/// reply against a client-side replay of the same hysteresis policy.
fn trade_advice(
    sock: &mut TcpStream,
    replay: &mut PolicyState,
    n: usize,
    seed: usize,
    meter: &Meter,
) {
    for step in 0..n {
        // a deterministic level walk that crosses both thresholds
        let level = match (seed + step) % 7 {
            0 | 1 | 2 => 0.9,
            3 | 4 | 5 => 0.1,
            _ => 0.5,
        };
        send_frame(sock, &control("level", level.to_le_bytes().to_vec()), meter).unwrap();
        let (reply, _) = recv_frame(sock, meter).unwrap();
        assert_eq!(reply.name, "advice", "level reply: {:?}", reply.name);
        let expected = replay.decide(level).wire();
        assert_eq!(
            reply.payload,
            expected.as_bytes(),
            "advice diverged from policy replay at step {step}"
        );
    }
}

#[test]
fn connection_storm_keeps_threads_bounded_and_advice_exact() {
    let _guard = SERIAL.lock().unwrap();
    // each loopback connection costs two descriptors in this process;
    // leave headroom for the suite's own files and sockets
    let target: usize = match raise_nofile_limit(65_536) {
        Ok(limit) => (((limit.saturating_sub(512)) / 2) as usize).min(10_000),
        Err(_) => 1_000,
    };
    assert!(target >= 500, "file-descriptor budget too small to storm");

    let policy = SwitchPolicy::default();
    let handle = FleetServer::start(
        Zoo::default(),
        FleetConfig {
            policy,
            ..FleetConfig::default()
        },
    )
    .unwrap();
    let addr = handle.addr;
    let accepts0 = registry().reactor.accepts.get();
    let active0 = registry().reactor.active_connections.get();

    // wave 1: every device connects, identifies itself, and stays online
    let per_thread = target.div_ceil(CLIENT_THREADS);
    let sockets: Vec<Vec<TcpStream>> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENT_THREADS)
            .map(|t| {
                s.spawn(move || {
                    let meter = Meter::default();
                    let mut mine = Vec::with_capacity(per_thread);
                    for i in 0..per_thread {
                        let mut sock = connect(addr);
                        hello(&mut sock, &format!("dev-{t}-{i}"), &meter);
                        // a sample of devices trades advice while the
                        // rest of the fleet is still connecting
                        if i % 8 == 0 {
                            let mut replay = PolicyState::new(policy, Variant::PartBit);
                            trade_advice(&mut sock, &mut replay, 5, t + i, &meter);
                        }
                        mine.push(sock);
                    }
                    mine
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    let held: usize = sockets.iter().map(|v| v.len()).sum();
    assert!(held >= target, "only {held} of {target} connections held");

    // the whole fleet is online: sessions are state, not threads
    let active = registry().reactor.active_connections.get();
    assert!(
        active >= active0 + target as u64,
        "gauge shows {active} active, expected >= {}",
        active0 + target as u64
    );
    assert!(
        registry().reactor.accepts.get() >= accepts0 + target as u64,
        "accept counter did not cover the storm"
    );
    let threads = server_thread_count();
    assert!(
        (1..=9).contains(&threads),
        "{threads} nq- threads serving {held} connections (want reactor + <=8 workers)"
    );

    // storm wave: half the fleet drops at once, new devices keep coming
    let mut sockets = sockets;
    for v in sockets.iter_mut() {
        v.truncate(v.len() / 2);
    }
    let survivors: usize = sockets.iter().map(|v| v.len()).sum();
    let meter = Meter::default();
    let mut fresh = Vec::new();
    for i in 0..64 {
        let mut sock = connect(addr);
        hello(&mut sock, &format!("late-{i}"), &meter);
        let mut replay = PolicyState::new(policy, Variant::PartBit);
        trade_advice(&mut sock, &mut replay, 7, i, &meter);
        fresh.push(sock);
    }

    // the reactor reaps the dropped half (readiness-driven EOF, no
    // timeout sweep needed); poll briefly for the gauge to settle
    let deadline = Instant::now() + Duration::from_secs(30);
    let want = active0 + (survivors + fresh.len()) as u64;
    loop {
        let now = registry().reactor.active_connections.get();
        if now <= want || Instant::now() > deadline {
            assert!(
                now <= want,
                "gauge stuck at {now}, expected <= {want} after the drop wave"
            );
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    // survivors still get exact advice after the churn
    for (t, v) in sockets.iter_mut().enumerate() {
        if let Some(sock) = v.first_mut() {
            // fresh device id: the old one's replay state is long gone
            hello(sock, &format!("survivor-{t}"), &meter);
        }
    }

    drop(sockets);
    drop(fresh);
    handle.stop();

    // after a full drain every reactor connection is gone
    assert_eq!(
        registry().reactor.active_connections.get(),
        active0,
        "connections leaked past shutdown"
    );
}

#[test]
fn per_device_rate_limit_refuses_excess_advice_requests() {
    let _guard = SERIAL.lock().unwrap();
    let handle = FleetServer::start(
        Zoo::default(),
        FleetConfig {
            // 2-token burst that effectively never refills: exactly two
            // advice requests per device get through
            rate_limit: Some(RateLimit {
                per_sec: 0.000_001,
                burst: 2.0,
            }),
            ..FleetConfig::default()
        },
    )
    .unwrap();
    let refused0 = registry().reactor.rate_limited.get();

    let meter = Meter::default();
    let mut sock = connect(handle.addr);
    hello(&mut sock, "greedy", &meter);
    let mut replies = Vec::new();
    for _ in 0..5 {
        send_frame(&mut sock, &control("level", 0.5f64.to_le_bytes().to_vec()), &meter).unwrap();
        let (reply, _) = recv_frame(&mut sock, &meter).unwrap();
        replies.push((reply.name, reply.payload));
    }
    assert_eq!(replies[0].0, "advice");
    assert_eq!(replies[1].0, "advice");
    for (name, payload) in &replies[2..] {
        assert_eq!(name, "error");
        assert_eq!(payload.as_slice(), b"rate limited");
    }
    assert_eq!(registry().reactor.rate_limited.get(), refused0 + 3);

    // a second device has its own bucket
    let mut other = connect(handle.addr);
    hello(&mut other, "patient", &meter);
    send_frame(&mut other, &control("level", 0.5f64.to_le_bytes().to_vec()), &meter).unwrap();
    let (reply, _) = recv_frame(&mut other, &meter).unwrap();
    assert_eq!(reply.name, "advice");

    drop(sock);
    drop(other);
    handle.stop();
}
