//! The deprecated `container` free-function shims must stay
//! byte-identical to the `store` path, so a later PR can delete them
//! with confidence: every pair below decodes/reads the same artifact
//! through both APIs and compares bytes (or re-serialized bytes), not
//! summaries.

#![allow(deprecated)] // the comparison target IS the deprecated API

use nestquant::container::{self, Container, TensorData};
use nestquant::store::{read_file_range, FileSource, NqArchive, Section, SectionSource};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_shims_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Field-wise equality for containers that cannot round-trip through
/// `serialize` (part-bit decodes have `w_low: None`).
fn assert_same_container(a: &Container, b: &Container) {
    assert_eq!(a.kind, b.kind);
    assert_eq!((a.n, a.h, a.act_bits), (b.n, b.h, b.act_bits));
    assert_eq!(a.name, b.name);
    assert_eq!(a.meta, b.meta);
    assert_eq!(a.section_b_offset, b.section_b_offset);
    assert_eq!(a.tensors.len(), b.tensors.len());
    for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!(ta.name, tb.name);
        assert_eq!(ta.shape, tb.shape);
        match (&ta.data, &tb.data) {
            (
                TensorData::Nest { scales: s1, w_high: h1, w_low: l1 },
                TensorData::Nest { scales: s2, w_high: h2, w_low: l2 },
            ) => {
                assert_eq!(s1, s2, "{}", ta.name);
                assert_eq!(h1.unpack(), h2.unpack(), "{}", ta.name);
                match (l1, l2) {
                    (Some(l1), Some(l2)) => assert_eq!(l1.unpack(), l2.unpack(), "{}", ta.name),
                    (None, None) => {}
                    _ => panic!("{}: w_low presence differs", ta.name),
                }
            }
            (TensorData::Fp32(v1), TensorData::Fp32(v2)) => assert_eq!(v1, v2, "{}", ta.name),
            (
                TensorData::Mono { scales: s1, w_int: w1 },
                TensorData::Mono { scales: s2, w_int: w2 },
            ) => {
                assert_eq!(s1, s2, "{}", ta.name);
                assert_eq!(w1.unpack(), w2.unpack(), "{}", ta.name);
            }
            _ => panic!("{}: payload kind differs", ta.name),
        }
    }
}

#[test]
fn probe_shim_equals_file_source_index() {
    let dir = temp_dir("probe");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(21, 8, 4, 48, 8).unwrap();
    container::write(&path, &c).unwrap();
    let shim = container::probe(&path).unwrap();
    let store = FileSource::new(&path).index().unwrap();
    assert_eq!(shim, store);
    assert_eq!(&shim, NqArchive::open(&path).unwrap().index());
}

#[test]
fn read_range_shim_equals_store_range_and_section_fetches() {
    let dir = temp_dir("range");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(22, 7, 3, 40, 6).unwrap();
    container::write(&path, &c).unwrap();
    let idx = container::probe(&path).unwrap();
    for range in [idx.section_a(), idx.section_b(), 3..17] {
        let shim = container::read_range(&path, range.clone()).unwrap();
        let store = read_file_range(&path, range.clone()).unwrap();
        assert_eq!(shim, store, "range {range:?}");
    }
    // section fetches through the source are the same bytes
    let src = FileSource::new(&path);
    assert_eq!(
        container::read_range(&path, idx.section_a()).unwrap(),
        &src.fetch(Section::A).unwrap()[..]
    );
    assert_eq!(
        container::read_range(&path, idx.section_b()).unwrap(),
        &src.fetch(Section::B).unwrap()[..]
    );
}

#[test]
fn read_and_parse_shims_equal_archive_decode_byte_for_byte() {
    let dir = temp_dir("decode");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(23, 8, 5, 56, 8).unwrap();
    container::write(&path, &c).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // full decode: both re-serialize to the identical artifact bytes
    let shim_full = container::read(&path, false).unwrap();
    let store_full = NqArchive::open(&path).unwrap().to_container(false).unwrap();
    let shim_bytes = container::serialize(&shim_full).unwrap();
    let store_bytes = container::serialize(&store_full).unwrap();
    assert_eq!(shim_bytes, store_bytes, "re-serialized decodes differ");
    assert_eq!(shim_bytes, bytes, "decode → serialize must be lossless");

    // part-bit decode (w_low = None cannot serialize; compare fields)
    let shim_part = container::read(&path, true).unwrap();
    let store_part = NqArchive::open(&path).unwrap().to_container(true).unwrap();
    assert_same_container(&shim_part, &store_part);

    // in-memory parse shim vs in-memory archive
    let shim_mem = container::parse(&bytes, false).unwrap();
    let store_mem = NqArchive::from_bytes(&bytes).unwrap().to_container(false).unwrap();
    assert_same_container(&shim_mem, &store_mem);
}

#[test]
fn section_b_shims_equal_archive_attach() {
    let dir = temp_dir("attach");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(24, 6, 4, 32, 4).unwrap();
    let (_, _, b_len) = container::write(&path, &c).unwrap();

    // legacy chain: part read + read_section_b
    let mut legacy = container::read(&path, true).unwrap();
    let paged = container::read_section_b(&path, &mut legacy).unwrap();
    assert_eq!(paged, b_len);

    // legacy attach from a raw blob
    let arch = NqArchive::open(&path).unwrap();
    let blob = arch.attach_b().unwrap();
    let mut attached = container::read(&path, true).unwrap();
    container::attach_section_b(&mut attached, &blob).unwrap();

    // store path: archive full decode
    let store = arch.to_container(false).unwrap();
    assert_same_container(&legacy, &attached);
    assert_same_container(&legacy, &store);
    // and all three re-serialize to the on-disk artifact
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(container::serialize(&legacy).unwrap(), bytes);
    assert_eq!(container::serialize(&attached).unwrap(), bytes);
    assert_eq!(container::serialize(&store).unwrap(), bytes);
}
