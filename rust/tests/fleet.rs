//! Fleet-distribution integration tests — artifact-independent: every
//! test builds synthetic nest containers on the fly, so tier-1 exercises
//! the whole subsystem (server, shared cache, resumable transfers,
//! policy-driven playback) offline.

use std::sync::Arc;
use std::time::Duration;

use nestquant::container::{self, TensorData};
use nestquant::coordinator::SwitchPolicy;
use nestquant::device::{MemoryLedger, ResourceTrace};
use nestquant::fleet::{FleetClient, FleetConfig, FleetServer, RemoteSource, Section, Zoo};
use nestquant::nest;
use nestquant::store::{FileSource, NqArchive, PayloadView, SectionSource};

const TIMEOUT: Duration = Duration::from_secs(30);

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_fleet_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a synthetic INT(n|h) container; returns (path, a_len, b_len).
fn write_synth(dir: &std::path::Path, name: &str, seed: u64, n: u8, h: u8) -> (std::path::PathBuf, u64, u64) {
    let path = dir.join(format!("{name}.nq"));
    let c = container::synthetic_nest(seed, n, h, 512, 16).unwrap();
    let (_, a, b) = container::write(&path, &c).unwrap();
    (path, a, b)
}

fn small_chunk_config() -> FleetConfig {
    FleetConfig {
        chunk_bytes: 512, // many chunks per section → meaningful resume
        ..FleetConfig::default()
    }
}

/// Acceptance: ≥2 devices pull the same container through the shared
/// cache — one disk read per section, wire-byte accounting balanced in
/// both directions, and every device reconstructs bit-identical weights.
#[test]
fn two_devices_share_cache_with_balanced_accounting() {
    let dir = temp_dir("share");
    let (path, a_len, b_len) = write_synth(&dir, "m0", 1, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, small_chunk_config()).unwrap();
    let addr = handle.addr;

    let cold = NqArchive::open(&path).unwrap().to_container(false).unwrap();
    let mut joins = Vec::new();
    for d in 0..3 {
        let cold = cold.clone();
        joins.push(std::thread::spawn(move || -> (u64, u64) {
            let mut c = FleetClient::connect(addr, &format!("dev{d}"), TIMEOUT).unwrap();
            let mut sec_a = Vec::new();
            let mut sec_b = Vec::new();
            let oa = c.pull_section("m0", Section::A, 0, &mut sec_a, None).unwrap();
            let ob = c.pull_section("m0", Section::B, 0, &mut sec_b, None).unwrap();
            assert!(oa.completed && ob.completed);
            // reconstruct: A ++ B is the whole artifact; opening it as an
            // in-memory archive yields bit-identical weights
            let mut whole = sec_a;
            whole.extend_from_slice(&sec_b);
            let got = NqArchive::from_bytes(&whole)
                .unwrap()
                .to_container(false)
                .unwrap();
            for (tg, tc) in got.tensors.iter().zip(&cold.tensors) {
                match (&tg.data, &tc.data) {
                    (
                        TensorData::Nest { w_high: h1, w_low: Some(l1), scales: s1 },
                        TensorData::Nest { w_high: h2, w_low: Some(l2), scales: s2 },
                    ) => {
                        assert_eq!(s1, s2);
                        assert_eq!(h1.unpack(), h2.unpack());
                        assert_eq!(l1.unpack(), l2.unpack());
                    }
                    (TensorData::Fp32(a), TensorData::Fp32(b)) => assert_eq!(a, b),
                    _ => panic!("payload mismatch"),
                }
            }
            c.wire()
        }));
    }
    let mut dev_sent = 0u64;
    let mut dev_received = 0u64;
    for j in joins {
        let (s, r) = j.join().unwrap();
        dev_sent += s;
        dev_received += r;
    }

    let cache = Arc::clone(&handle.cache);
    let sessions = Arc::clone(&handle.sessions);
    let meter = Arc::clone(&handle.meter);
    let latency = Arc::clone(&handle.xfer_latency);
    handle.stop(); // joins every handler → accounting is final

    // wire bytes balance in both directions
    let (srv_sent, srv_received) = meter.snapshot();
    assert_eq!(srv_sent, dev_received, "server sent == devices received");
    assert_eq!(srv_received, dev_sent, "server received == devices sent");

    // the shared cache read each section from disk exactly once
    let s = cache.stats();
    assert_eq!(s.misses, 2, "one disk read per section");
    assert_eq!(s.hits, 4, "two later devices hit per section");
    assert_eq!(s.disk_bytes, a_len + b_len);
    assert_eq!(sessions.device_count(), 3);
    // every completed transfer recorded a latency sample (3 devices × 2)
    assert_eq!(latency.count(), 6);
    for summary in sessions.summaries() {
        assert_eq!(summary.resident_sections, 2);
        assert_eq!(summary.bytes_sent, a_len + b_len);
        assert_eq!(summary.bytes_resent, 0);
    }
}

/// Acceptance: a transfer killed mid-Section-B resumes from the last
/// acked chunk; total re-sent bytes are strictly less than a full
/// restart, and the resumed bytes are bit-identical to a cold read.
#[test]
fn killed_section_b_transfer_resumes_from_last_ack() {
    let dir = temp_dir("resume");
    let (path, _a_len, b_len) = write_synth(&dir, "m0", 2, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let config = small_chunk_config();
    let chunk = config.chunk_bytes as u64;
    let total_chunks = b_len.div_ceil(chunk);
    assert!(total_chunks >= 4, "section B too small for the scenario");
    let handle = FleetServer::start(zoo, config).unwrap();

    // phase 1: pull section B but die after acking 2 chunks
    let killed_after = 2u64;
    let mut sink = Vec::new();
    {
        let mut victim = FleetClient::connect(handle.addr, "flaky", TIMEOUT).unwrap();
        let out = victim
            .pull_section("m0", Section::B, 0, &mut sink, Some(killed_after as usize))
            .unwrap();
        assert!(!out.completed);
        assert_eq!(out.received_to, killed_after * chunk);
        // dropping the client cuts the TCP connection mid-transfer
    }

    // wait (bounded) for the server to process the final ack
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while handle.sessions.acked("flaky", "m0", Section::B) != killed_after * chunk {
        assert!(
            std::time::Instant::now() < deadline,
            "server never recorded the last acked chunk (acked={})",
            handle.sessions.acked("flaky", "m0", Section::B)
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // phase 2: reconnect under the same device id and resume
    let mut back = FleetClient::connect(handle.addr, "flaky", TIMEOUT).unwrap();
    let resume_from = back.server_offset("m0", Section::B).unwrap();
    assert_eq!(resume_from, killed_after * chunk);
    let out = back
        .pull_section("m0", Section::B, resume_from, &mut sink, None)
        .unwrap();
    assert!(out.completed);
    assert_eq!(out.total_len, b_len);
    // the resumed pull moved strictly less than a full restart
    assert!(out.payload_bytes < b_len, "{} !< {b_len}", out.payload_bytes);
    assert_eq!(out.payload_bytes, b_len - resume_from);

    // total re-sent bytes: only the chunk that was in flight when the
    // connection died — strictly less than a restart-from-zero would be
    let progress = handle.sessions.progress("flaky", "m0", Section::B).unwrap();
    assert!(progress.complete);
    // at most the one in-flight (sent, unacked) chunk is re-sent; whether
    // it was sent before the connection died is a benign race
    assert!(progress.bytes_resent <= chunk, "{}", progress.bytes_resent);
    assert!(progress.bytes_resent < b_len);
    assert!(progress.bytes_sent >= b_len && progress.bytes_sent <= b_len + chunk);
    assert!(
        progress.bytes_sent < 2 * b_len,
        "resume must beat a full restart: {} vs {}",
        progress.bytes_sent,
        2 * b_len
    );

    // the reassembled section is bit-identical to the on-disk tail
    let disk_b = FileSource::new(&path).fetch(Section::B).unwrap();
    assert_eq!(&sink[..], &disk_b[..]);
    drop(back);
    handle.stop();
}

/// Satellite: a paged full→part→full switch over the fleet transport —
/// driven through a remote-source archive — produces bit-identical
/// weights to a cold full load, with zero section-A re-fetches across
/// the cycle.
#[test]
fn paged_switch_is_bit_identical_to_cold_load() {
    let dir = temp_dir("paged");
    let (path, a_len, b_len) = write_synth(&dir, "m0", 3, 8, 5);

    // cold load: local archive
    let cold_arch = NqArchive::open(&path).unwrap();
    let cold = cold_arch.full_bit().unwrap();
    let cfg = nest::NestConfig::new(cold_arch.index().n, cold_arch.index().h).unwrap();

    // paged load: the same model as a remote archive over the fleet
    // transport — identical API, bytes come down the wire
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, small_chunk_config()).unwrap();
    let remote = RemoteSource::connect(handle.addr, "pager", "m0", TIMEOUT).unwrap();
    assert_eq!(remote.model(), "m0");
    let arch = NqArchive::with_source(Arc::new(remote)).unwrap();
    assert_eq!(arch.index(), cold_arch.index());

    // part-bit state: w_low absent in the typed view
    let part = arch.part_bit().unwrap();
    assert!(matches!(
        part.tensor(0).payload(),
        PayloadView::Nest { w_low: None, .. }
    ));
    drop(part);

    // upgrade → downgrade → upgrade: only section B moves
    let full = arch.full_bit().unwrap();
    drop(full);
    assert!(arch.release_b());
    let full = arch.full_bit().unwrap();

    // full-bit weights decoded through the fused upgrade kernel match
    // the cold load bit-for-bit — and the fused one-pass decode matches
    // the legacy unpack→recompose→dequant composition on the wire bytes
    for (tp, tc) in full.tensors().zip(cold.tensors()) {
        if let (
            PayloadView::Nest { scales: s1, w_high: h1, w_low: Some(l1) },
            PayloadView::Nest { scales: s2, w_high: h2, w_low: Some(l2) },
        ) = (tp.payload(), tc.payload())
        {
            let (mut sc_paged, mut sc_cold) = (Vec::new(), Vec::new());
            s1.read_into(&mut sc_paged);
            s2.read_into(&mut sc_cold);
            let (mut w_paged, mut w_cold) = (Vec::new(), Vec::new());
            h1.recompose_dequant_into(&l1, cfg.l(), &sc_paged, &mut w_paged);
            h2.recompose_dequant_into(&l2, cfg.l(), &sc_cold, &mut w_cold);
            assert_eq!(w_paged, w_cold);
            let mut rec = Vec::new();
            nest::recompose_into(&h1.unpack(), &l1.unpack(), cfg.l(), &mut rec);
            let mut legacy = Vec::new();
            nestquant::quant::dequant(&rec, &sc_paged, &mut legacy);
            assert_eq!(w_paged, legacy, "fused ≡ legacy on paged bytes");
        }
    }

    // byte accounting: A once, B twice (one per upgrade), zero re-parses
    let s = arch.stats();
    assert_eq!(s.a_fetches, 1);
    assert_eq!(s.b_fetches, 2);
    assert_eq!(s.layout_parses, 1);
    assert_eq!(s.a_bytes_fetched, a_len);
    assert_eq!(s.b_bytes_fetched, 2 * b_len);
    drop(full);
    drop(arch);
    handle.stop();
}

/// Remote-source hardening: a fetch runs under a whole-transfer
/// deadline, so a stalled transfer errors out (resumably) instead of
/// wedging the archive open forever.
#[test]
fn remote_fetch_deadline_fails_fast_and_recovers() {
    let dir = temp_dir("fetchto");
    let (path, a_len, _b) = write_synth(&dir, "m0", 9, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, small_chunk_config()).unwrap();

    // an already-expired deadline must error — not hang — even against
    // a healthy server, and the error must advertise resumability
    let mut source = RemoteSource::connect(handle.addr, "impatient", "m0", TIMEOUT)
        .unwrap()
        .with_fetch_timeout(Some(Duration::ZERO));
    let err = source.fetch(Section::A).unwrap_err().to_string();
    assert!(err.contains("timed out"), "unexpected error: {err}");

    // recovery on the SAME source: the aborted pull poisoned its
    // connection, so fetch must have reconnected under the hood — with a
    // sane deadline the very next fetch succeeds with clean bytes
    source.set_fetch_timeout(Some(TIMEOUT));
    let a = source.fetch(Section::A).unwrap();
    assert_eq!(a.len() as u64, a_len);
    drop(source);
    handle.stop();
}

/// Policy-driven playback: devices follow upgrade/downgrade advice from
/// the server's hysteresis policy; paging traffic is Section-B-sized.
#[test]
fn playback_pages_only_section_b_deltas() {
    let dir = temp_dir("playback");
    let (path, a_len, b_len) = write_synth(&dir, "m0", 4, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let config = FleetConfig {
        chunk_bytes: 1024,
        policy: SwitchPolicy::default(),
        ..FleetConfig::default()
    };
    let handle = FleetServer::start(zoo, config).unwrap();

    // a discharge→recharge→discharge trace that forces switches
    let mut levels = Vec::new();
    levels.extend_from_slice(&[0.9; 4]); // upgrade
    levels.extend_from_slice(&[0.2; 4]); // downgrade
    levels.extend_from_slice(&[0.9; 4]); // upgrade again
    let trace = ResourceTrace::new(levels);

    let mut client = FleetClient::connect(handle.addr, "cam0", TIMEOUT).unwrap();
    let mut ledger = MemoryLedger::new(1 << 30);
    let report = client.playback("m0", trace, &mut ledger).unwrap();

    assert_eq!(report.steps, 12);
    assert_eq!(report.upgrades, 2);
    assert_eq!(report.downgrades, 1);
    assert_eq!(report.section_a_bytes, a_len);
    assert_eq!(report.section_b_bytes, b_len);
    // traffic = one A provisioning + one B per upgrade, nothing else
    assert_eq!(report.payload_pulled, a_len + 2 * b_len);
    // ledger: A resident + B resident (final state is full-bit)
    assert_eq!(ledger.used(), a_len + b_len);
    let stats = ledger.stats();
    assert_eq!(stats.page_in_bytes, a_len + 2 * b_len);
    assert_eq!(stats.page_out_bytes, b_len);
    drop(client);

    // reconnect under the same device id: the server session persisted
    // full-bit, so a second playback reconciles — this fresh process has
    // no local Section B, so the reconcile re-pulls the real bytes (a
    // server-side ack history must never zero-fill device memory) — and
    // can then follow a downgrade cleanly
    let mut again = FleetClient::connect(handle.addr, "cam0", TIMEOUT).unwrap();
    let mut ledger2 = MemoryLedger::new(1 << 30);
    let trace2 = ResourceTrace::new(vec![0.2; 4]);
    let report2 = again.playback("m0", trace2, &mut ledger2).unwrap();
    assert_eq!(report2.downgrades, 1);
    assert_eq!(report2.upgrades, 0);
    assert_eq!(report2.payload_pulled, a_len + b_len, "reconcile re-pulls B");
    assert_eq!(ledger2.used(), a_len, "B paged out by the downgrade");
    drop(again);
    handle.stop();
}

/// Satellite: a device discovers served models by id (`models`
/// command) and opens one as a `RemoteSource`-backed archive — no
/// paths, no out-of-band configuration.
#[test]
fn models_listing_feeds_remote_source_by_id() {
    let dir = temp_dir("models");
    let (p0, a_len, _) = write_synth(&dir, "m0", 6, 8, 4);
    let (_p1, _, _) = write_synth(&dir, "m1", 7, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &p0);
    zoo.add("m1", dir.join("m1.nq"));
    let handle = FleetServer::start(zoo, small_chunk_config()).unwrap();

    let mut c = FleetClient::connect(handle.addr, "scout", TIMEOUT).unwrap();
    let ids = c.models().unwrap();
    assert_eq!(ids, vec!["m0".to_string(), "m1".to_string()]);
    drop(c);

    // open the first listed id through the store — index and section A
    // come down the wire, typed views work as if local
    let remote = RemoteSource::connect(handle.addr, "scout", ids[0].clone(), TIMEOUT).unwrap();
    let arch = NqArchive::with_source(Arc::new(remote)).unwrap();
    let part = arch.part_bit().unwrap();
    assert!(!part.is_empty());
    assert_eq!(arch.stats().a_bytes_fetched, a_len);
    drop(part);
    handle.stop();
}

/// Server-side errors reply cleanly instead of wedging the connection.
#[test]
fn unknown_model_and_missing_hello_are_clean_errors() {
    let dir = temp_dir("errors");
    let (path, _, _) = write_synth(&dir, "m0", 5, 8, 4);
    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, small_chunk_config()).unwrap();

    let mut c = FleetClient::connect(handle.addr, "dev", TIMEOUT).unwrap();
    let mut sink = Vec::new();
    let err = c.pull_section("ghost", Section::A, 0, &mut sink, None).unwrap_err();
    assert!(format!("{err}").contains("unknown model"), "{err}");
    // the connection is still usable afterwards
    let out = c.pull_section("m0", Section::A, 0, &mut sink, None).unwrap();
    assert!(out.completed);
    // a pull offset beyond the section errors cleanly too
    let err = c
        .pull_section("m0", Section::A, out.total_len + 1, &mut sink, None)
        .unwrap_err();
    assert!(format!("{err}").contains("beyond"), "{err}");
    drop(c);
    handle.stop();
}
