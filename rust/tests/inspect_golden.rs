//! Golden-output smoke test for `nestquant inspect`: run the real
//! binary (`CARGO_BIN_EXE_nestquant`) on a deterministic synthetic
//! `.nq` and compare against a checked-in fixture.
//!
//! Normalization: the temp path becomes `<PATH>`, digit runs become
//! `#`, and space runs collapse — so the fixture pins the *structure*
//! (section lines, per-tensor table, cost line) without columns
//! shifting when byte counts change width. The exact byte counts are
//! asserted separately below, rendered through the same format strings
//! the CLI uses, so the numbers are still golden — just not the
//! padding.

use nestquant::container::{self, Kind};

/// Digit runs → `#`, space runs → one space, trailing space trimmed.
fn normalize(text: &str, path: &str) -> String {
    let mut out = String::new();
    for line in text.lines() {
        let line = line.replace(path, "<PATH>");
        let mut norm = String::new();
        let mut in_digits = false;
        let mut in_spaces = false;
        for ch in line.chars() {
            if ch.is_ascii_digit() {
                if !in_digits {
                    norm.push('#');
                }
                in_digits = true;
                in_spaces = false;
            } else if ch == ' ' || ch == '\t' {
                if !in_spaces {
                    norm.push(' ');
                }
                in_spaces = true;
                in_digits = false;
            } else {
                norm.push(ch);
                in_digits = false;
                in_spaces = false;
            }
        }
        out.push_str(norm.trim_end());
        out.push('\n');
    }
    out.trim_end().to_string()
}

#[test]
fn inspect_output_matches_golden_fixture() {
    let dir = std::env::temp_dir().join(format!("nq_inspect_golden_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("golden.nq");
    // fully deterministic: fixed seed, shapes, and nest config
    let c = container::synthetic_nest(0x601D, 8, 4, 48, 8).unwrap();
    let (total, a_len, b_len) = container::write(&path, &c).unwrap();

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_nestquant"))
        .arg("inspect")
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "inspect failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout).unwrap();

    // exact numbers, rendered through the CLI's own format strings
    assert!(
        text.contains(&format!(
            "kind {:?}  name {:?}  INT({}|{})  act_bits {}",
            Kind::Nest,
            "synthetic_24605",
            8,
            4,
            8
        )),
        "header line missing:\n{text}"
    );
    assert!(
        text.contains(&format!(
            "section A [{:>10}, {:>10}) {:>10} B",
            0, a_len, a_len
        )),
        "section A byte range missing:\n{text}"
    );
    // the file length includes the integrity trailer; section B ends at
    // the payload boundary before it
    assert_eq!(total, a_len + b_len + container::TRAILER_LEN as u64);
    assert!(
        text.contains(&format!(
            "section B [{:>10}, {:>10}) {:>10} B",
            a_len,
            a_len + b_len,
            b_len
        )),
        "section B byte range missing:\n{text}"
    );
    assert!(
        text.contains("checksums crc64 A="),
        "checksum status line missing:\n{text}"
    );
    assert!(
        text.contains(&format!("{:<24} {:<14} {:>9}", "layer.w", "48x8", 48 * 8)),
        "weight tensor row missing:\n{text}"
    );

    // structural golden: the checked-in fixture, byte counts normalized
    let normalized = normalize(&text, &path.display().to_string());
    let golden = include_str!("fixtures/inspect_golden.txt").trim_end();
    assert_eq!(
        normalized, golden,
        "normalized inspect output diverged from tests/fixtures/inspect_golden.txt\n\
         --- got ---\n{normalized}\n--- want ---\n{golden}"
    );
}
