//! Integration tests over the real artifacts: runtime → coordinator →
//! switching → serving, cross-checked against the Python pipeline's
//! golden outputs.
//!
//! These tests skip (with a notice) when `make artifacts` hasn't run —
//! unit tests cover everything artifact-independent.

use std::sync::{Arc, Mutex};

use nestquant::container::{Kind, TensorData};
use nestquant::store::NqArchive;
use nestquant::coordinator::{server, Coordinator, State, SwitchPolicy, Variant};
use nestquant::device::{MemoryLedger, ResourceTrace};
use nestquant::nest;
use nestquant::runtime::{Engine, Manifest};
use nestquant::util::read_f32_file;

fn root() -> Option<std::path::PathBuf> {
    let r = nestquant::artifacts_dir();
    if r.join("manifest.json").exists() {
        Some(r)
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        None
    }
}

/// Smallest arch with full artifacts — keeps compile times short.
const ARCH: &str = "cnn_t";

fn nest_combo(manifest: &Manifest, arch: &str) -> (u8, u8) {
    let spec = manifest.model(arch).unwrap();
    // prefer INT(8|4); otherwise the first available
    if spec.nest_container(8, 4).is_some() {
        (8, 4)
    } else {
        let k = spec.nest_containers.keys().next().expect("no nest containers");
        let (n, h) = k.split_once('|').unwrap();
        (n.parse().unwrap(), h.parse().unwrap())
    }
}

/// PJRT execution of the shipped HLO reproduces the Python pipeline's
/// golden logits bit-close — the strongest cross-language check.
#[test]
fn golden_logits_match_python() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let spec = manifest.model(ARCH).unwrap();
    let engine = Engine::cpu().unwrap();

    // FP32 weights through the a0 graph
    let exe = engine
        .load_hlo(&manifest.abs(&spec.hlo[&0u8]))
        .unwrap();
    let c = NqArchive::open(manifest.abs(&spec.fp32_container))
        .unwrap()
        .to_container(false)
        .unwrap();
    let mut bufs = Vec::new();
    for (t, p) in c.tensors.iter().zip(&spec.params) {
        match &t.data {
            TensorData::Fp32(vals) => bufs.push(engine.upload(vals, &p.shape).unwrap()),
            _ => panic!("fp32 container"),
        }
    }
    let (x, _) = manifest.load_val().unwrap();
    let img_len = manifest.img * manifest.img * manifest.channels;
    let input = engine
        .upload(
            &x[..manifest.batch * img_len],
            &[manifest.batch, manifest.img, manifest.img, manifest.channels],
        )
        .unwrap();
    let logits = exe.run(&input, &bufs).unwrap();

    let golden = read_f32_file(&manifest.abs(&spec.expected["a0_fp32"])).unwrap();
    assert_eq!(logits.len(), golden.len());
    for (i, (a, b)) in logits.iter().zip(&golden).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
            "logit {i}: rust {a} vs python {b}"
        );
    }
}

/// Full-bit accuracy via the coordinator matches the pipeline's recorded
/// full-bit accuracy for the same container.
#[test]
fn full_bit_accuracy_matches_pipeline() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    c.manager.load_full_bit(&mut c.ledger).unwrap();
    let acc = c.eval_accuracy(Some(512)).unwrap();

    // the container's meta JSON records the pipeline's full-bit accuracy
    let meta_str = NqArchive::open(
        manifest.abs(manifest.model(ARCH).unwrap().nest_container(n, h).unwrap()),
    )
    .unwrap()
    .layout()
    .unwrap()
    .meta()
    .to_string();
    let meta = nestquant::util::json::parse(&meta_str).unwrap();
    let want = meta.path(&["full_acc"]).unwrap().as_f64().unwrap();
    assert!(
        (acc - want).abs() < 0.06,
        "rust full-bit acc {acc} vs pipeline {want} (512-subset tolerance)"
    );
}

/// The switching lifecycle: part → upgrade → downgrade, with exact byte
/// accounting and lossless full-bit reconstruction.
#[test]
fn switch_lifecycle_accounting() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    let (sec_a, sec_b) = c.manager.section_bytes();
    assert!(sec_a > 0 && sec_b > 0);

    let cost = c.manager.load_part_bit(&mut c.ledger).unwrap();
    assert_eq!(cost.page_in_bytes, sec_a);
    assert_eq!(c.ledger.used(), sec_a);
    let part_acc = c.eval_accuracy(Some(256)).unwrap();

    // upgrade: page-in == section B, page-out == 0
    let cost = c.manager.upgrade(&mut c.ledger).unwrap();
    assert_eq!(cost.page_in_bytes, sec_b);
    assert_eq!(cost.page_out_bytes, 0);
    assert_eq!(c.ledger.used(), sec_a + sec_b);
    let full_acc = c.eval_accuracy(Some(256)).unwrap();

    // downgrade: page-in == 0, page-out == section B
    let cost = c.manager.downgrade(&mut c.ledger).unwrap();
    assert_eq!(cost.page_in_bytes, 0);
    assert_eq!(cost.page_out_bytes, sec_b);
    assert_eq!(c.ledger.used(), sec_a);
    let part_acc2 = c.eval_accuracy(Some(256)).unwrap();
    assert_eq!(part_acc, part_acc2, "downgrade must restore part-bit exactly");

    // re-upgrade must reproduce the full-bit numbers exactly
    c.manager.upgrade(&mut c.ledger).unwrap();
    let full_acc2 = c.eval_accuracy(Some(256)).unwrap();
    assert_eq!(full_acc, full_acc2, "upgrade must be lossless");

    assert_eq!(c.manager.state(), State::Active(Variant::FullBit));
    let stats = c.ledger.stats();
    assert_eq!(stats.page_in_bytes, sec_a + 2 * sec_b);
    assert_eq!(stats.page_out_bytes, sec_b);
}

/// Invalid transitions are rejected without corrupting state.
#[test]
fn invalid_transitions_rejected() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    assert!(c.manager.upgrade(&mut c.ledger).is_err());
    assert!(c.manager.downgrade(&mut c.ledger).is_err());
    c.manager.load_part_bit(&mut c.ledger).unwrap();
    assert!(c.manager.load_part_bit(&mut c.ledger).is_err());
    assert!(c.manager.downgrade(&mut c.ledger).is_err()); // already part
    c.manager.upgrade(&mut c.ledger).unwrap();
    assert!(c.manager.upgrade(&mut c.ledger).is_err()); // already full
    // state survived the failed calls
    assert_eq!(c.manager.state(), State::Active(Variant::FullBit));
    assert!(c.eval_accuracy(Some(64)).is_ok());
}

/// Page-in must fail cleanly under memory pressure and leave the
/// part-bit model serving (the paper's downgrade-to-survive story).
#[test]
fn upgrade_fails_under_memory_pressure() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    let (sec_a, _) = c.manager.section_bytes();
    c.ledger.set_capacity(sec_a); // room for part-bit only
    c.manager.load_part_bit(&mut c.ledger).unwrap();
    assert!(c.manager.upgrade(&mut c.ledger).is_err());
    // still serving part-bit
    assert_eq!(c.manager.state(), State::Active(Variant::PartBit));
    assert!(c.eval_accuracy(Some(64)).is_ok());
}

/// A resource trace drives upgrades/downgrades; NestQuant moves only
/// section-B bytes, ever.
#[test]
fn trace_switches_move_only_section_b() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    let (_, sec_b) = c.manager.section_bytes();
    let report = c
        .run_trace(ResourceTrace::solar_day(24), SwitchPolicy::default(), 16)
        .unwrap();
    assert!(
        !report.switches.is_empty(),
        "solar trace must trigger at least one switch"
    );
    for s in &report.switches {
        match s.to {
            Variant::FullBit => {
                assert_eq!(s.cost.page_in_bytes, sec_b);
                assert_eq!(s.cost.page_out_bytes, 0);
            }
            Variant::PartBit => {
                assert_eq!(s.cost.page_in_bytes, 0);
                assert_eq!(s.cost.page_out_bytes, sec_b);
            }
        }
    }
    assert!(report.full_served + report.part_served > 0);
}

/// The TCP server answers concurrent clients with correct predictions.
#[test]
fn server_roundtrip_concurrent_clients() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    c.manager.load_full_bit(&mut c.ledger).unwrap();
    let (x, y) = c.manifest.load_val().unwrap();
    let img_len = manifest.img * manifest.img * manifest.channels;
    let classes = manifest.num_classes;

    let coord = Arc::new(Mutex::new(c));
    let handle = server::serve(coord, server::ServerConfig::default()).unwrap();
    let addr = handle.addr;

    let mut joins = Vec::new();
    for t in 0..4 {
        let x0 = x[t * img_len..(t + 1) * img_len].to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = server::Client::connect(addr).unwrap();
            let logits = client.infer(&x0).unwrap();
            assert_eq!(logits.len(), classes);
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32
        }));
    }
    let mut correct = 0;
    for (t, j) in joins.into_iter().enumerate() {
        if j.join().unwrap() == y[t] {
            correct += 1;
        }
    }
    // a trained model over 4 easy images: expect most right
    assert!(correct >= 2, "only {correct}/4 correct via server");
    handle.stop();
}

/// Bad requests get error replies, not hangs or crashes.
#[test]
fn server_rejects_malformed_image() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let mut c = Coordinator::new(&root, ARCH, n, h).unwrap();
    c.manager.load_full_bit(&mut c.ledger).unwrap();
    let coord = Arc::new(Mutex::new(c));
    let handle = server::serve(coord, server::ServerConfig::default()).unwrap();
    let mut client = server::Client::connect(handle.addr).unwrap();
    let err = client.infer(&[0.0; 7]).unwrap_err();
    assert!(format!("{err}").contains("bad image size"));
    handle.stop();
}

/// The container's part-bit weights agree with re-deriving w_high from
/// the mono INT8 container (pipeline consistency across formats).
#[test]
fn container_cross_consistency() {
    let Some(root) = root() else { return };
    let manifest = Manifest::load(&root).unwrap();
    let spec = manifest.model(ARCH).unwrap();
    let (n, h) = nest_combo(&manifest, ARCH);
    let nest_c = NqArchive::open(manifest.abs(spec.nest_container(n, h).unwrap()))
        .unwrap()
        .to_container(false)
        .unwrap();
    let mono_c = NqArchive::open(manifest.abs(&spec.mono_containers[&n]))
        .unwrap()
        .to_container(false)
        .unwrap();
    assert_eq!(nest_c.kind, Kind::Nest);
    assert_eq!(mono_c.kind, Kind::Mono);
    let cfg = nest::NestConfig::new(n, h).unwrap();
    for (tn, tm) in nest_c.tensors.iter().zip(&mono_c.tensors) {
        let (TensorData::Nest { w_high, w_low, .. }, TensorData::Mono { w_int, .. }) =
            (&tn.data, &tm.data)
        else {
            continue;
        };
        // recomposed nest weights == the mono INTn weights, everywhere
        let hs = w_high.unpack();
        let ls = w_low.as_ref().unwrap().unpack();
        let wi = w_int.unpack();
        for i in 0..hs.len() {
            assert_eq!(
                nest::recompose(hs[i], ls[i], cfg.l()),
                wi[i],
                "{}[{}]",
                tn.name,
                i
            );
        }
    }
}
