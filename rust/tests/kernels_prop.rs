//! Property tests: every kernel dispatch tier (scalar ≡ SWAR ≡ SIMD,
//! pinned via `kernels::plan_for` — the same tiers `NQ_KERNEL` selects
//! process-wide) is bit-identical to the legacy
//! `unpack → recompose → dequant` composition — over every legal
//! `(n, h)`, compensated and uncompensated `w_low`, channel counts that
//! do and don't divide the lane block, and lengths not divisible by
//! `lanes(bits)` (the padded-final-word edge). Channel counts always
//! divide the element count — a mis-dividing count is rejected by the
//! kernels (pinned by unit tests in `kernels::mod`).
//!
//! The int-domain GEMM gets the same treatment: all tiers bitwise
//! identical (including i32 wraparound), and in the exact float regime
//! the whole dequantization-free forward is bitwise equal to
//! decode-then-matmul for every legal `(n, h)`.

use nestquant::bits::{int_range, lanes, PackedTensor};
use nestquant::container;
use nestquant::kernels::{self, Tier};
use nestquant::nest::{self, NestConfig, Rounding};
use nestquant::quant;
use nestquant::store::{NqArchive, PayloadView};
use nestquant::util::prng::Rng;
use nestquant::util::propcheck;

/// Scales that exercise the real range (positive, mixed magnitudes).
fn gen_scales(r: &mut Rng, c: usize) -> Vec<f32> {
    (0..c).map(|_| (r.f64() * 0.1 + 1e-4) as f32).collect()
}

/// Legacy part-bit composition: unpack to i32, inflate a scale copy,
/// dequant.
fn legacy_unpack_dequant(t: &PackedTensor, scales: &[f32], mul: f32) -> Vec<f32> {
    let mut ints = Vec::new();
    t.unpack_into(&mut ints);
    let inflated: Vec<f32> = scales.iter().map(|&s| s * mul).collect();
    let mut out = Vec::new();
    quant::dequant(&ints, &inflated, &mut out);
    out
}

/// Legacy four-pass upgrade composition: unpack ×2, recompose, dequant.
fn legacy_recompose_dequant(
    hi: &PackedTensor,
    lo: &PackedTensor,
    l: u8,
    scales: &[f32],
) -> Vec<f32> {
    let (mut hs, mut ls, mut rec) = (Vec::new(), Vec::new(), Vec::new());
    hi.unpack_into(&mut hs);
    lo.unpack_into(&mut ls);
    nest::recompose_into(&hs, &ls, l, &mut rec);
    let mut out = Vec::new();
    quant::dequant(&rec, scales, &mut out);
    out
}

/// Lengths biased to straddle word boundaries of `bits` (±1 around lane
/// multiples plus a plain random tail).
fn gen_len(r: &mut Rng, scale: f64, bits: u8) -> usize {
    let n_lanes = lanes(bits);
    let base = ((300.0 * scale) as usize).max(1);
    match r.index(4) {
        0 => (r.index(6) + 1) * n_lanes + 1,
        1 => ((r.index(6) + 1) * n_lanes).saturating_sub(1).max(1),
        2 => (r.index(6) + 1) * n_lanes,
        _ => r.index(base) + 1,
    }
}

/// Part-bit launch kernel ≡ legacy composition for every packable
/// bitwidth (SWAR-aligned and not), every channel phase, and the
/// padded-final-word edge.
#[test]
fn fused_unpack_dequant_equals_composition() {
    for bits in 2..=16u8 {
        propcheck::check(
            &format!("kernels-unpack-dequant-{bits}"),
            40,
            move |r: &mut Rng, scale| {
                let len = gen_len(r, scale, bits);
                let opts = [1usize, 2, 3, 4, 7, 8, 16, 32, 33, len.max(1)];
                let c = opts[r.index(opts.len())];
                // channel count must divide the element count (the
                // kernels reject a mis-dividing count) — round up to
                // the next multiple, keeping the word-straddle bias
                let len = len.div_ceil(c) * c;
                let (lo, hi) = int_range(bits);
                let vals: Vec<i32> =
                    (0..len).map(|_| r.int(lo as i64, hi as i64) as i32).collect();
                let scales = gen_scales(r, c);
                let mul = *[1.0f32, 2.0, 16.0, 0.5].get(r.index(4)).unwrap();
                (vals, scales, mul)
            },
            move |(vals, scales, mul)| {
                let t = PackedTensor::pack(vals, bits).unwrap();
                let bytes = t.to_le_bytes();
                let want = legacy_unpack_dequant(&t, scales, *mul);
                // the module-level entry (active plan) and every pinned
                // tier must all match the composition bit-for-bit
                let mut got = Vec::new();
                kernels::unpack_dequant_into(&bytes, bits, vals.len(), scales, *mul, &mut got);
                if got != want {
                    return false;
                }
                Tier::all().into_iter().all(|tier| {
                    kernels::plan_for(tier)
                        .unpack_dequant_into(&bytes, bits, vals.len(), scales, *mul, &mut got);
                    got == want
                })
            },
        );
    }
}

/// Upgrade kernel ≡ legacy four-pass composition over every legal
/// `(n, h)` with a packable `w_high`, both compensated (`l+1` bits, the
/// on-disk format) and uncompensated (`l` bits) residuals, and every
/// rounding method for the decomposition.
#[test]
fn fused_recompose_dequant_equals_composition_all_nh() {
    for n in 3..=16u8 {
        for h in 2..n {
            let cfg = NestConfig::new(n, h).unwrap();
            for compensate in [true, false] {
                let low_bits = if compensate { cfg.low_bits() } else { cfg.l() };
                if low_bits < 2 {
                    continue; // 1-bit residuals are not packable
                }
                propcheck::check(
                    &format!("kernels-recompose-n{n}-h{h}-comp{compensate}"),
                    6,
                    move |r: &mut Rng, scale| {
                        let len = gen_len(r, scale, if r.bool() { h } else { low_bits });
                        let opts = [1usize, 2, 3, 5, 8, 16, 64];
                        let c = opts[r.index(opts.len())];
                        let len = len.div_ceil(c) * c;
                        let (lo, hi) = int_range(n);
                        let vals: Vec<i32> =
                            (0..len).map(|_| r.int(lo as i64, hi as i64) as i32).collect();
                        let scales = gen_scales(r, c);
                        let method = *[Rounding::BitShift, Rounding::Rtn, Rounding::Up]
                            .get(r.index(3))
                            .unwrap();
                        (vals, scales, method)
                    },
                    move |(vals, scales, method)| {
                        let (hs, ls) = nest::decompose(vals, cfg, *method, compensate);
                        let th = PackedTensor::pack(&hs, h).unwrap();
                        let tl = PackedTensor::pack(&ls, low_bits).unwrap();
                        let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
                        let want = legacy_recompose_dequant(&th, &tl, cfg.l(), scales);
                        let mut got = Vec::new();
                        Tier::all().into_iter().all(|tier| {
                            kernels::plan_for(tier).recompose_dequant_into(
                                &hb,
                                h,
                                &lb,
                                low_bits,
                                cfg.l(),
                                vals.len(),
                                scales,
                                &mut got,
                            );
                            got == want
                        })
                    },
                );
            }
        }
    }
}

/// The i32 unpack path agrees across tiers and with the owned
/// `PackedTensor` decode for every width and padded-final-word edge.
#[test]
fn unpack_ints_equals_packed_tensor_all_tiers() {
    for bits in 2..=16u8 {
        propcheck::check(
            &format!("kernels-unpack-ints-{bits}"),
            30,
            move |r: &mut Rng, scale| {
                let len = gen_len(r, scale, bits);
                let (lo, hi) = int_range(bits);
                (0..len).map(|_| r.int(lo as i64, hi as i64) as i32).collect::<Vec<i32>>()
            },
            move |vals| {
                let t = PackedTensor::pack(vals, bits).unwrap();
                let bytes = t.to_le_bytes();
                let mut got = Vec::new();
                Tier::all().into_iter().all(|tier| {
                    kernels::plan_for(tier).unpack_ints_into(&bytes, bits, vals.len(), &mut got);
                    got == *vals
                })
            },
        );
    }
}

/// The `NQ_KERNEL` contract: every documented value resolves to its
/// tier, unknown values fall back to the default instead of failing,
/// and requesting the SIMD tier is safe on ANY host — on machines
/// without AVX2 it resolves to the SSE2/NEON/SWAR fallback and still
/// decodes correctly (no panic, no wrong bytes). This is the graceful-
/// fallback guarantee: dispatch may change speed, never results.
#[test]
fn env_override_and_graceful_fallback() {
    assert_eq!(kernels::tier_from_env(Some("scalar")), Tier::Scalar);
    assert_eq!(kernels::tier_from_env(Some("swar")), Tier::Swar);
    assert_eq!(kernels::tier_from_env(Some("SIMD")), Tier::Simd);
    assert_eq!(kernels::tier_from_env(Some("not-a-tier")), Tier::Simd);
    assert_eq!(kernels::tier_from_env(None), Tier::Simd);

    // plan_for never panics for any tier on any host, and whatever
    // sub-path Simd resolved to still decodes bit-identically
    // (8 values over 2 channels — counts must divide)
    let t = PackedTensor::pack(&[-3, 1, 4, -1, 5, -2, 6, 3], 5).unwrap();
    let scales = [0.25f32, 0.5];
    let mut want = Vec::new();
    kernels::plan_for(Tier::Scalar)
        .unpack_dequant_into(&t.to_le_bytes(), 5, 8, &scales, 2.0, &mut want);
    for tier in Tier::all() {
        let plan = kernels::plan_for(tier);
        assert!(!plan.path.is_empty(), "{tier}: path must be resolved");
        let mut got = Vec::new();
        plan.unpack_dequant_into(&t.to_le_bytes(), 5, 8, &scales, 2.0, &mut got);
        assert_eq!(got, want, "tier {tier} (path {})", plan.path);
    }
}

/// The store's fused view entry points equal the legacy view
/// composition on a real archive — both variants, straight from the
/// section bytes of a synthetic container grid.
#[test]
fn packed_view_fused_paths_equal_composition() {
    for (seed, n, h, rows, c) in [
        (11u64, 8u8, 4u8, 33, 6),
        (12, 8, 5, 64, 16),
        (13, 6, 3, 47, 5),
        (14, 16, 8, 21, 4),
        (15, 5, 2, 130, 1),
    ] {
        let cont = container::synthetic_nest(seed, n, h, rows, c).unwrap();
        let arch = NqArchive::from_container(&cont).unwrap();
        let cfg = NestConfig::new(n, h).unwrap();
        let full = arch.full_bit().unwrap();
        for t in full.tensors() {
            let PayloadView::Nest {
                scales,
                w_high,
                w_low: Some(w_low),
            } = t.payload()
            else {
                continue;
            };
            let mut sc = Vec::new();
            scales.read_into(&mut sc);

            // part-bit: fused vs unpack + inflate + dequant
            let mut fused = Vec::new();
            w_high.unpack_dequant_into(&sc, cfg.scale_inflation(), &mut fused);
            let mut ints = Vec::new();
            w_high.unpack_into(&mut ints);
            let inflated: Vec<f32> =
                sc.iter().map(|&s| s * cfg.scale_inflation()).collect();
            let mut legacy = Vec::new();
            quant::dequant(&ints, &inflated, &mut legacy);
            assert_eq!(fused, legacy, "part-bit INT({n}|{h}) {}", t.name());

            // full-bit: fused vs the four-pass composition
            let mut fused_full = Vec::new();
            w_high.recompose_dequant_into(&w_low, cfg.l(), &sc, &mut fused_full);
            let (mut hs, mut ls, mut rec) = (Vec::new(), Vec::new(), Vec::new());
            w_high.unpack_into(&mut hs);
            w_low.unpack_into(&mut ls);
            nest::recompose_into(&hs, &ls, cfg.l(), &mut rec);
            let mut legacy_full = Vec::new();
            quant::dequant(&rec, &sc, &mut legacy_full);
            assert_eq!(fused_full, legacy_full, "full-bit INT({n}|{h}) {}", t.name());
        }
    }
}

/// The int-domain GEMM is bitwise identical across every dispatch tier
/// for every packable width — including full-range i32 activations
/// that force wraparound (the contract is wrapping arithmetic, so
/// overflow is defined and must agree between the scalar cursor, the
/// SWAR word decoder, and whatever vector sub-path SIMD resolved to),
/// and row x class shapes whose tails straddle packed words.
#[test]
fn gemm_tiers_bit_identical_all_widths() {
    for bits in 2..=16u8 {
        propcheck::check(
            &format!("kernels-gemm-{bits}"),
            30,
            move |r: &mut Rng, scale| {
                let opts = [1usize, 2, 3, 5, 8, 16, 33];
                let classes = opts[r.index(opts.len())];
                let rows = r.index(((40.0 * scale) as usize).max(1)) + 1;
                let (lo, hi) = int_range(bits);
                let vals: Vec<i32> = (0..rows * classes)
                    .map(|_| r.int(lo as i64, hi as i64) as i32)
                    .collect();
                let x: Vec<i32> = (0..rows)
                    .map(|_| r.int(i32::MIN as i64, i32::MAX as i64) as i32)
                    .collect();
                (vals, x, classes)
            },
            move |(vals, x, classes)| {
                let t = PackedTensor::pack(vals, bits).unwrap();
                let bytes = t.to_le_bytes();
                let mut want = Vec::new();
                kernels::plan_for(Tier::Scalar)
                    .gemm_i32_into(&bytes, bits, x, *classes, &mut want);
                // naive wrapping reference, independent of the cursor
                let mut naive = vec![0i32; *classes];
                for (row, &xv) in vals.chunks(*classes).zip(x.iter()) {
                    for (a, &w) in naive.iter_mut().zip(row) {
                        *a = a.wrapping_add(xv.wrapping_mul(w));
                    }
                }
                if want != naive {
                    return false;
                }
                let mut got = Vec::new();
                Tier::all().into_iter().all(|tier| {
                    kernels::plan_for(tier).gemm_i32_into(&bytes, bits, x, *classes, &mut got);
                    got == want
                })
            },
        );
    }
}

/// In the exact float regime — power-of-two scales, integer-grid
/// activations on a power-of-two step, partial sums far below 2^24 —
/// every term of the f32-decode matmul is exactly representable, so
/// the dequantization-free forward must be *bitwise* equal to
/// decode-then-matmul: part-bit (`s·2^l·w_high`) and full-bit
/// (`s·(w_high·2^l + w_low)` recomposed in the i64 epilogue), every
/// legal `(n, h)`, every tier. Outside this regime the paths differ
/// only by activation-quantization error (bounded at the tenant
/// level); in it, any mismatch is a kernel or epilogue bug.
#[test]
fn int_domain_forward_equals_f32_decode_in_exact_regime() {
    for n in 3..=16u8 {
        for h in 2..n {
            let cfg = NestConfig::new(n, h).unwrap();
            if cfg.low_bits() < 2 {
                continue; // 1-bit residuals are not packable
            }
            let mut r = Rng::new(0x6E37 ^ ((n as u64) << 8) ^ h as u64);
            for (rows, classes) in [(1usize, 1usize), (7, 5), (13, 3), (16, 8)] {
                let len = rows * classes;
                let (lo, hi) = int_range(n);
                let w_int: Vec<i32> =
                    (0..len).map(|_| r.int(lo as i64, hi as i64) as i32).collect();
                let (hs, ls) = nest::decompose(&w_int, cfg, Rounding::BitShift, true);
                let th = PackedTensor::pack(&hs, h).unwrap();
                let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
                let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
                // pow2 scales and activation step: every f32 product
                // and partial sum below is exact (|x_int| ≤ 8,
                // |w| ≤ 2^15, rows ≤ 16 → sums < 2^23 < 2^24)
                let scales: Vec<f32> =
                    (0..classes).map(|c| 0.25 / (1u32 << (c % 4)) as f32).collect();
                let x_int: Vec<i32> = (0..rows).map(|_| r.int(-8, 8) as i32).collect();
                let sx = 0.0078125f32; // 2^-7
                let x: Vec<f32> = x_int.iter().map(|&v| v as f32 * sx).collect();

                let mut w_part = Vec::new();
                kernels::unpack_dequant_into(
                    &hb,
                    h,
                    len,
                    &scales,
                    cfg.scale_inflation(),
                    &mut w_part,
                );
                let mut w_full = Vec::new();
                kernels::recompose_dequant_into(
                    &hb,
                    h,
                    &lb,
                    cfg.low_bits(),
                    cfg.l(),
                    len,
                    &scales,
                    &mut w_full,
                );
                let matmul = |w: &[f32]| -> Vec<f32> {
                    let mut out = vec![0f32; classes];
                    for (row, &xv) in w.chunks(classes).zip(&x) {
                        for (o, &wv) in out.iter_mut().zip(row) {
                            *o += xv * wv;
                        }
                    }
                    out
                };
                let want_part = matmul(&w_part);
                let want_full = matmul(&w_full);

                let (mut acc_hi, mut acc_lo) = (Vec::new(), Vec::new());
                for tier in Tier::all() {
                    let plan = kernels::plan_for(tier);
                    plan.gemm_i32_into(&hb, h, &x_int, classes, &mut acc_hi);
                    let got_part: Vec<f32> = acc_hi
                        .iter()
                        .zip(&scales)
                        .map(|(&a, &s)| a as f32 * (sx * (cfg.scale_inflation() * s)))
                        .collect();
                    assert_eq!(
                        got_part, want_part,
                        "part-bit INT({n}|{h}) {rows}x{classes} tier {tier}"
                    );
                    plan.gemm_i32_into(&lb, cfg.low_bits(), &x_int, classes, &mut acc_lo);
                    let got_full: Vec<f32> = (0..classes)
                        .map(|c| {
                            let v = ((acc_hi[c] as i64) << cfg.l()) + acc_lo[c] as i64;
                            v as f32 * (sx * scales[c])
                        })
                        .collect();
                    assert_eq!(
                        got_full, want_full,
                        "full-bit INT({n}|{h}) {rows}x{classes} tier {tier}"
                    );
                }
            }
        }
    }
}
