//! Property tests: nest decompose→recompose round-trips across every
//! legal (n, h) combination, driven by `util::propcheck` — the §3.3.2
//! compensation claim verified exhaustively rather than for the paper's
//! n=8 table alone.

use nestquant::bits::{int_range, PackedTensor};
use nestquant::container;
use nestquant::nest::{self, NestConfig, Rounding};
use nestquant::util::propcheck;

const METHODS: [Rounding; 4] = [
    Rounding::BitShift,
    Rounding::Rtn,
    Rounding::Up,
    Rounding::Down,
];

/// With the 1-bit compensation, decompose→recompose is lossless for every
/// legal (n, h), every rounding method, and every representable INTn
/// value — randomized vectors via propcheck on top of the range logic.
#[test]
fn compensated_roundtrip_lossless_all_combinations() {
    for n in 2..=16u8 {
        for h in 1..n {
            let cfg = NestConfig::new(n, h).unwrap();
            let (lo, hi) = int_range(n);
            for method in METHODS {
                propcheck::check(
                    &format!("nest-roundtrip-n{n}-h{h}-{method:?}"),
                    8,
                    |rng, scale| propcheck::vec_i64(rng, scale, 256, lo as i64, hi as i64),
                    |values| {
                        let w: Vec<i32> = values.iter().map(|&v| v as i32).collect();
                        let (hs, ls) = nest::decompose(&w, cfg, method, true);
                        let mut rec = Vec::new();
                        nest::recompose_into(&hs, &ls, cfg.l(), &mut rec);
                        rec == w
                    },
                );
            }
        }
    }
}

/// The exhaustive version over every representable value (cheap: ≤ 65536
/// values per combination).
#[test]
fn compensated_roundtrip_exhaustive_small_n() {
    for n in 2..=12u8 {
        for h in 1..n {
            let cfg = NestConfig::new(n, h).unwrap();
            let (lo, hi) = int_range(n);
            for method in METHODS {
                for w in lo..=hi {
                    let wh = nest::high_of(w, cfg, method);
                    let wl = nest::low_of(w, wh, cfg, true);
                    assert_eq!(
                        nest::recompose(wh, wl, cfg.l()),
                        w,
                        "INT({n}|{h}) {method:?} w={w}"
                    );
                    // the compensated residual really fits in l+1 bits
                    let (llo, lhi) = int_range(cfg.low_bits());
                    assert!(wl >= llo && wl <= lhi, "INT({n}|{h}) w={w} wl={wl}");
                }
            }
        }
    }
}

/// Round-trip through the packed representation (the container path):
/// pack(w_high) + pack(w_low) → unpack → recompose, for every (n, h)
/// where both sections pack (h ≥ 2).
#[test]
fn packed_roundtrip_all_packable_combinations() {
    for n in 3..=16u8 {
        for h in 2..n {
            let cfg = NestConfig::new(n, h).unwrap();
            let (lo, hi) = int_range(n);
            propcheck::check(
                &format!("nest-packed-n{n}-h{h}"),
                4,
                |rng, scale| propcheck::vec_i64(rng, scale, 200, lo as i64, hi as i64),
                |values| {
                    let w: Vec<i32> = values.iter().map(|&v| v as i32).collect();
                    let (hs, ls) = nest::decompose(&w, cfg, Rounding::Rtn, true);
                    let ph = PackedTensor::pack(&hs, cfg.h).unwrap();
                    let pl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
                    let mut rec = Vec::new();
                    nest::recompose_into(&ph.unpack(), &pl.unpack(), cfg.l(), &mut rec);
                    rec == w
                },
            );
        }
    }
}

/// Full container serialize→open round-trip across the (n, h) grid:
/// an owned decode of the archive and its part-bit + attached-B views
/// agree for every combination the container format can hold.
#[test]
fn container_roundtrip_across_grid() {
    use nestquant::store::{NqArchive, PayloadView};
    for n in [4u8, 6, 8, 12, 16] {
        for h in 2..n {
            let c = container::synthetic_nest(u64::from(n) * 100 + u64::from(h), n, h, 24, 4)
                .unwrap();
            let arch = NqArchive::from_container(&c).unwrap();
            let full = arch.to_container(false).unwrap();
            let view = arch.full_bit().unwrap();
            for (tf, tp) in full.tensors.iter().zip(view.tensors()) {
                match (&tf.data, tp.payload()) {
                    (
                        container::TensorData::Nest { w_high: h1, w_low: Some(l1), .. },
                        PayloadView::Nest { w_high: h2, w_low: Some(l2), .. },
                    ) => {
                        assert_eq!(h1.unpack(), h2.unpack(), "INT({n}|{h})");
                        assert_eq!(l1.unpack(), l2.unpack(), "INT({n}|{h})");
                    }
                    (container::TensorData::Fp32(a), PayloadView::Fp32(b)) => {
                        assert_eq!(*a, b.to_vec())
                    }
                    _ => panic!("INT({n}|{h}): payload shape mismatch"),
                }
            }
        }
    }
}
