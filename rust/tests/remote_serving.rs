//! End-to-end remote serving: a `ModelManager` whose archive lives
//! behind a fleet server (`ModelManager::from_archive` over a
//! `fleet::RemoteSource`) — the device serves a model it never had on
//! disk, and the full upgrade/downgrade cycle moves exactly the
//! section-B delta over the wire. Closes the ROADMAP remote-hardening
//! bullet, and proves the integrity trailer end-to-end: every section
//! that crosses the wire is checksum-verified after chunked reassembly,
//! and a tampered artifact is refused at upgrade time instead of
//! serving flipped weights.

#![cfg(not(feature = "pjrt"))] // the toy HLO must not be compiled

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use nestquant::container;
use nestquant::coordinator::ModelManager;
use nestquant::device::MemoryLedger;
use nestquant::fleet::{FleetConfig, FleetServer, RemoteSource, Zoo};
use nestquant::runtime::{Engine, ModelSpec, ParamSpec};
use nestquant::store::{NqArchive, SectionSource};
use nestquant::telemetry::Snapshot;
use nestquant::transport::{recv_frame, send_frame, Frame, FrameKind, Meter};

const TIMEOUT: Duration = Duration::from_secs(30);

/// Failpoints are process-global (`inject_disconnect_after_chunks` arms
/// the `client.chunk` site, and *every* chunk pull checks it), so the
/// tests in this binary serialize instead of racing the registry's
/// per-site skip/fire counters.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Scrape the fleet server's `metrics` wire command (no `hello` needed:
/// monitoring carries no device identity).
fn scrape_fleet_metrics(addr: std::net::SocketAddr) -> Snapshot {
    let mut sock = std::net::TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(TIMEOUT)).unwrap();
    let meter = Meter::default();
    send_frame(
        &mut sock,
        &Frame {
            kind: FrameKind::Control,
            name: "metrics".into(),
            payload: Vec::new(),
        },
        &meter,
    )
    .unwrap();
    let (reply, _) = recv_frame(&mut sock, &meter).unwrap();
    assert_eq!(reply.name, "metrics", "unexpected reply");
    Snapshot::from_json(std::str::from_utf8(&reply.payload).unwrap()).unwrap()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_remote_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn toy_spec(rows: usize, channels: usize) -> ModelSpec {
    ModelSpec {
        name: "toy".into(),
        params: vec![
            ParamSpec {
                name: "layer.w".into(),
                shape: vec![rows, channels],
                quantized: true,
            },
            ParamSpec {
                name: "layer.b".into(),
                shape: vec![channels],
                quantized: false,
            },
        ],
        hlo: BTreeMap::from([(8u8, "toy.hlo.txt".to_string())]),
        nest_containers: BTreeMap::from([("8|4".to_string(), "m0.nq".to_string())]),
        mono_containers: BTreeMap::new(),
        fp32_container: String::new(),
        expected: BTreeMap::new(),
    }
}

/// The headline demo: boot a fleet server, open the archive through a
/// `RemoteSource`, and drive a real `ModelManager` through launch →
/// upgrade → downgrade → upgrade. Byte accounting proves the switch
/// economics survive the wire: section A crosses once, each upgrade
/// re-pulls exactly section B, downgrades move nothing.
#[test]
fn model_manager_serves_from_remote_archive() {
    let _serial = serial();
    let dir = temp_dir("serve");
    let c = container::synthetic_nest(41, 8, 4, 128, 16).unwrap();
    let (_, a_len, b_len) = container::write(&dir.join("m0.nq"), &c).unwrap();
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();

    let mut zoo = Zoo::new();
    zoo.add("m0", dir.join("m0.nq"));
    let handle = FleetServer::start(
        zoo,
        FleetConfig {
            chunk_bytes: 512, // several chunks per section: real reassembly
            ..FleetConfig::default()
        },
    )
    .unwrap();

    // scrape the wire command before any section moves: deltas below
    // are this test's contribution (>= because sibling tests in this
    // binary share the process-global registry)
    let before = scrape_fleet_metrics(handle.addr);

    let remote = RemoteSource::connect(handle.addr, "dev-remote", "m0", TIMEOUT).unwrap();
    let archive = Arc::new(NqArchive::with_source(Arc::new(remote)).unwrap());
    // the index crossed the wire with checksums intact
    assert!(archive.index().checksums.is_some());

    let engine = Engine::cpu().unwrap();
    let mut mgr =
        ModelManager::from_archive(&engine, toy_spec(128, 16), 8, &dir, Arc::clone(&archive))
            .unwrap();
    assert_eq!(mgr.section_bytes(), (a_len, b_len));

    let mut ledger = MemoryLedger::new(1 << 30);
    let launch = mgr.load_part_bit(&mut ledger).unwrap();
    assert_eq!(launch.page_in_bytes, a_len);

    let up = mgr.upgrade(&mut ledger).unwrap();
    assert_eq!(up.page_in_bytes, b_len);
    assert_eq!(up.page_out_bytes, 0);
    let down = mgr.downgrade(&mut ledger).unwrap();
    assert_eq!(down.page_in_bytes, 0);
    let up2 = mgr.upgrade(&mut ledger).unwrap();
    assert_eq!(up2.page_in_bytes, b_len);

    // remote archive accounting: A crossed the wire once, B per upgrade,
    // layout parsed once — identical economics to a local file
    let s = archive.stats();
    assert_eq!(s.a_fetches, 1);
    assert_eq!(s.b_fetches, 2);
    assert_eq!(s.layout_parses, 1);
    assert_eq!(s.a_bytes_fetched, a_len);
    assert_eq!(s.b_bytes_fetched, 2 * b_len);

    mgr.unload(&mut ledger).unwrap();
    assert_eq!(ledger.used(), 0);

    // telemetry satellite: the scraped deltas agree with ArchiveStats —
    // everything the archive says it fetched crossed the wire in
    // counted, acked chunks
    let after = scrape_fleet_metrics(handle.addr);
    let d = |name: &str| after.counter(name).unwrap() - before.counter(name).unwrap();
    assert!(d("nq_fleet_sessions") >= 1, "hello registered a session");
    assert!(d("nq_fleet_chunks_sent") >= 1);
    assert!(
        d("nq_fleet_chunk_bytes_sent") >= s.a_bytes_fetched + s.b_bytes_fetched,
        "chunk bytes {} must cover the archive's fetched bytes {}",
        d("nq_fleet_chunk_bytes_sent"),
        s.a_bytes_fetched + s.b_bytes_fetched
    );
    // the server-local transfer histogram rode along as an extra
    let xfer = after.histogram("nq_fleet_xfer_latency").unwrap();
    assert!(xfer.count >= 1, "completed transfers recorded");
    handle.stop();
}

/// Reconnect-and-resume satellite: a pull that dies mid-transfer
/// resumes from the server's last acked chunk instead of byte zero.
/// The fetch still completes, checksum-verified, and the registry's
/// resumed/restarted byte split accounts for every byte of the
/// interrupted first attempt.
#[test]
fn interrupted_fetch_resumes_from_acked_chunk() {
    let _serial = serial();
    nestquant::faults::clear();
    const CHUNK: u64 = 256;
    const FAULT_AFTER: u64 = 3;

    let dir = temp_dir("resume");
    let c = container::synthetic_nest(43, 8, 4, 128, 16).unwrap();
    let (_, a_len, _) = container::write(&dir.join("m0.nq"), &c).unwrap();
    assert!(a_len > FAULT_AFTER * CHUNK, "section A must outlast the fault");

    let mut zoo = Zoo::new();
    zoo.add("m0", dir.join("m0.nq"));
    let handle = FleetServer::start(
        zoo,
        FleetConfig {
            chunk_bytes: CHUNK as usize,
            ..FleetConfig::default()
        },
    )
    .unwrap();

    let remote = Arc::new(RemoteSource::connect(handle.addr, "dev-resume", "m0", TIMEOUT).unwrap());
    // the NEXT pull drops its connection after 3 acked chunks — the
    // deterministic stand-in for a flaky edge link
    remote.inject_disconnect_after_chunks(FAULT_AFTER as usize);

    let reg = nestquant::telemetry::registry();
    let resumed0 = reg.fleet.resumed_bytes.get();
    let restarted0 = reg.fleet.restarted_bytes.get();

    let src: Arc<dyn SectionSource> = Arc::clone(&remote);
    let archive = NqArchive::with_source(src).unwrap();
    // the section-A fetch hits the fault, reconnects, resumes, completes
    archive.part_bit().unwrap();

    let s = archive.stats();
    assert_eq!(s.a_fetches, 1, "one logical fetch despite the retry");
    assert_eq!(s.a_bytes_fetched, a_len, "reassembled section is complete");

    // every byte of the interrupted attempt is accounted: kept (resumed
    // from the server's ack) + rewound (re-pulled). No sibling test
    // injects faults, so these deltas are exactly this test's.
    let resumed = reg.fleet.resumed_bytes.get() - resumed0;
    let restarted = reg.fleet.restarted_bytes.get() - restarted0;
    assert_eq!(
        resumed + restarted,
        FAULT_AFTER * CHUNK,
        "interrupted attempt had acked exactly {FAULT_AFTER} chunks"
    );
    assert!(resumed > 0, "resume must keep acked bytes, not restart from zero");

    // and the fleet server's scrape shows the same counters on the wire
    let snap = scrape_fleet_metrics(handle.addr);
    assert!(snap.counter("nq_fleet_resumed_bytes").unwrap() >= resumed);
    nestquant::faults::clear();
    handle.stop();
}

/// Integrity end-to-end: flip one payload byte of the artifact on the
/// server's disk. The header still parses, geometry still checks out —
/// only the trailer checksum catches it, and the device's upgrade fails
/// loudly instead of serving flipped weights.
#[test]
fn tampered_remote_artifact_is_refused() {
    let _serial = serial();
    let dir = temp_dir("tamper");
    let c = container::synthetic_nest(42, 8, 4, 64, 8).unwrap();
    let path = dir.join("m0.nq");
    container::write(&path, &c).unwrap();
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();

    // flip one bit in the middle of section B, leaving header + trailer
    let mut bytes = std::fs::read(&path).unwrap();
    let idx = {
        let src = nestquant::store::FileSource::new(&path);
        src.index().unwrap()
    };
    let b = idx.section_b();
    let victim = (b.start + (b.end - b.start) / 2) as usize;
    bytes[victim] ^= 0x04;
    std::fs::write(&path, &bytes).unwrap();

    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, FleetConfig::default()).unwrap();

    let remote = RemoteSource::connect(handle.addr, "dev-tamper", "m0", TIMEOUT).unwrap();
    let archive = Arc::new(NqArchive::with_source(Arc::new(remote)).unwrap());
    let engine = Engine::cpu().unwrap();
    let mut mgr =
        ModelManager::from_archive(&engine, toy_spec(64, 8), 8, &dir, Arc::clone(&archive))
            .unwrap();
    let mut ledger = MemoryLedger::new(1 << 30);
    // section A is intact: the part-bit launch still works
    mgr.load_part_bit(&mut ledger).unwrap();
    // the upgrade pulls the tampered section B and must refuse it
    let err = mgr.upgrade(&mut ledger).unwrap_err();
    assert!(
        format!("{err:#}").contains("checksum"),
        "expected a checksum failure, got: {err:#}"
    );
    // the manager still serves part-bit and the ledger balanced back
    assert_eq!(ledger.used(), idx.section_a_bytes());
    handle.stop();
}
