//! End-to-end remote serving: a `ModelManager` whose archive lives
//! behind a fleet server (`ModelManager::from_archive` over a
//! `fleet::RemoteSource`) — the device serves a model it never had on
//! disk, and the full upgrade/downgrade cycle moves exactly the
//! section-B delta over the wire. Closes the ROADMAP remote-hardening
//! bullet, and proves the integrity trailer end-to-end: every section
//! that crosses the wire is checksum-verified after chunked reassembly,
//! and a tampered artifact is refused at upgrade time instead of
//! serving flipped weights.

#![cfg(not(feature = "pjrt"))] // the toy HLO must not be compiled

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use nestquant::container;
use nestquant::coordinator::ModelManager;
use nestquant::device::MemoryLedger;
use nestquant::fleet::{FleetConfig, FleetServer, RemoteSource, Zoo};
use nestquant::runtime::{Engine, ModelSpec, ParamSpec};
use nestquant::store::NqArchive;

const TIMEOUT: Duration = Duration::from_secs(30);

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_remote_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn toy_spec(rows: usize, channels: usize) -> ModelSpec {
    ModelSpec {
        name: "toy".into(),
        params: vec![
            ParamSpec {
                name: "layer.w".into(),
                shape: vec![rows, channels],
                quantized: true,
            },
            ParamSpec {
                name: "layer.b".into(),
                shape: vec![channels],
                quantized: false,
            },
        ],
        hlo: BTreeMap::from([(8u8, "toy.hlo.txt".to_string())]),
        nest_containers: BTreeMap::from([("8|4".to_string(), "m0.nq".to_string())]),
        mono_containers: BTreeMap::new(),
        fp32_container: String::new(),
        expected: BTreeMap::new(),
    }
}

/// The headline demo: boot a fleet server, open the archive through a
/// `RemoteSource`, and drive a real `ModelManager` through launch →
/// upgrade → downgrade → upgrade. Byte accounting proves the switch
/// economics survive the wire: section A crosses once, each upgrade
/// re-pulls exactly section B, downgrades move nothing.
#[test]
fn model_manager_serves_from_remote_archive() {
    let dir = temp_dir("serve");
    let c = container::synthetic_nest(41, 8, 4, 128, 16).unwrap();
    let (_, a_len, b_len) = container::write(&dir.join("m0.nq"), &c).unwrap();
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();

    let mut zoo = Zoo::new();
    zoo.add("m0", dir.join("m0.nq"));
    let handle = FleetServer::start(
        zoo,
        FleetConfig {
            chunk_bytes: 512, // several chunks per section: real reassembly
            ..FleetConfig::default()
        },
    )
    .unwrap();

    let remote = RemoteSource::connect(handle.addr, "dev-remote", "m0", TIMEOUT).unwrap();
    let archive = Arc::new(NqArchive::with_source(Arc::new(remote)).unwrap());
    // the index crossed the wire with checksums intact
    assert!(archive.index().checksums.is_some());

    let engine = Engine::cpu().unwrap();
    let mut mgr =
        ModelManager::from_archive(&engine, toy_spec(128, 16), 8, &dir, Arc::clone(&archive))
            .unwrap();
    assert_eq!(mgr.section_bytes(), (a_len, b_len));

    let mut ledger = MemoryLedger::new(1 << 30);
    let launch = mgr.load_part_bit(&mut ledger).unwrap();
    assert_eq!(launch.page_in_bytes, a_len);

    let up = mgr.upgrade(&mut ledger).unwrap();
    assert_eq!(up.page_in_bytes, b_len);
    assert_eq!(up.page_out_bytes, 0);
    let down = mgr.downgrade(&mut ledger).unwrap();
    assert_eq!(down.page_in_bytes, 0);
    let up2 = mgr.upgrade(&mut ledger).unwrap();
    assert_eq!(up2.page_in_bytes, b_len);

    // remote archive accounting: A crossed the wire once, B per upgrade,
    // layout parsed once — identical economics to a local file
    let s = archive.stats();
    assert_eq!(s.a_fetches, 1);
    assert_eq!(s.b_fetches, 2);
    assert_eq!(s.layout_parses, 1);
    assert_eq!(s.a_bytes_fetched, a_len);
    assert_eq!(s.b_bytes_fetched, 2 * b_len);

    mgr.unload(&mut ledger).unwrap();
    assert_eq!(ledger.used(), 0);
    handle.stop();
}

/// Integrity end-to-end: flip one payload byte of the artifact on the
/// server's disk. The header still parses, geometry still checks out —
/// only the trailer checksum catches it, and the device's upgrade fails
/// loudly instead of serving flipped weights.
#[test]
fn tampered_remote_artifact_is_refused() {
    let dir = temp_dir("tamper");
    let c = container::synthetic_nest(42, 8, 4, 64, 8).unwrap();
    let path = dir.join("m0.nq");
    container::write(&path, &c).unwrap();
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();

    // flip one bit in the middle of section B, leaving header + trailer
    let mut bytes = std::fs::read(&path).unwrap();
    let idx = {
        let src = nestquant::store::FileSource::new(&path);
        use nestquant::store::SectionSource;
        src.index().unwrap()
    };
    let b = idx.section_b();
    let victim = (b.start + (b.end - b.start) / 2) as usize;
    bytes[victim] ^= 0x04;
    std::fs::write(&path, &bytes).unwrap();

    let mut zoo = Zoo::new();
    zoo.add("m0", &path);
    let handle = FleetServer::start(zoo, FleetConfig::default()).unwrap();

    let remote = RemoteSource::connect(handle.addr, "dev-tamper", "m0", TIMEOUT).unwrap();
    let archive = Arc::new(NqArchive::with_source(Arc::new(remote)).unwrap());
    let engine = Engine::cpu().unwrap();
    let mut mgr =
        ModelManager::from_archive(&engine, toy_spec(64, 8), 8, &dir, Arc::clone(&archive))
            .unwrap();
    let mut ledger = MemoryLedger::new(1 << 30);
    // section A is intact: the part-bit launch still works
    mgr.load_part_bit(&mut ledger).unwrap();
    // the upgrade pulls the tampered section B and must refuse it
    let err = mgr.upgrade(&mut ledger).unwrap_err();
    assert!(
        format!("{err:#}").contains("checksum"),
        "expected a checksum failure, got: {err:#}"
    );
    // the manager still serves part-bit and the ledger balanced back
    assert_eq!(ledger.used(), idx.section_a_bytes());
    handle.stop();
}
