//! Every report command must run green against the built artifacts —
//! these are the regeneration paths for all paper tables/figures.

use nestquant::report;

fn root() -> Option<std::path::PathBuf> {
    let r = nestquant::artifacts_dir();
    if r.join("manifest.json").exists() {
        Some(r)
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        None
    }
}

#[test]
fn artifact_free_reports() {
    // these never touch artifacts/ and must always work
    report::cmd_errors().unwrap();
    report::cmd_storage_ideal().unwrap();
    report::cmd_hardware().unwrap();
    report::cmd_libraries().unwrap();
    report::cmd_ablation_packing().unwrap();
}

#[test]
fn artifact_backed_reports() {
    let Some(root) = root() else { return };
    report::cmd_storage(&root, None).unwrap();
    report::cmd_switching(&root).unwrap();
    report::cmd_nesting_test(&root, "cnn_m").unwrap();
    report::cmd_nesting(&root, Some("cnn"), 8).unwrap();
    report::cmd_nesting(&root, Some("vit"), 8).unwrap();
    report::cmd_nesting(&root, None, 6).unwrap();
    report::cmd_cliff(&root).unwrap();
    report::cmd_combos(&root).unwrap();
    report::cmd_comparison(&root).unwrap();
    report::cmd_ptq_cost(&root).unwrap();
    report::cmd_ablations(&root).unwrap();
}

#[test]
fn similarity_report() {
    let Some(root) = root() else { return };
    report::cmd_similarity(&root, "cnn_t").unwrap();
}

#[test]
fn traffic_report_live_tcp() {
    let Some(root) = root() else { return };
    report::cmd_traffic(&root, Some("mobile")).unwrap();
}
