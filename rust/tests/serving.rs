//! Multi-tenant serving integration tests — the adversarial suite of
//! the ModelStore serving PR, artifact-independent and PJRT-free.
//!
//! What is proven here (byte-accounted, not narrated):
//!
//! 1. **No torn weights, ever**: ≥ 4 concurrent client threads hammer a
//!    server hosting ≥ 3 models while a background thread flips each
//!    model between part-bit and full-bit. Every single reply must be
//!    bit-identical to that model's part-bit OR full-bit single-tenant
//!    baseline — a switch landing mid-batch, a cross-tenant routing
//!    slip, or a half-rebuilt weight buffer all surface as a reply that
//!    matches neither.
//! 2. **Budget ceiling holds at every sample point**: a racing sampler
//!    asserts resident Section-B bytes ≤ cap throughout an eviction
//!    storm, against both the budget ledger and the archives' own
//!    residency.
//! 3. **Zero section-A re-reads / re-parses** across all upgrades,
//!    downgrades, and forced evictions (`ArchiveStats`).
//! 4. **Deterministic shutdown**: repeated start/stop cycles (flag-only,
//!    client-`stop`-frame, and idle-connection variants) join every
//!    thread and never hang.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nestquant::container;
use nestquant::coordinator::server::{serve_tenants, Client, ServerConfig, TenantExecutor};
use nestquant::coordinator::tenant::{nest_tenants_from_dir, NestTenant};
use nestquant::coordinator::{Decision, Variant};
use nestquant::store::{ModelStore, NqArchive, StoreBudget};
use nestquant::telemetry::{validate_prometheus, Snapshot};
use nestquant::util::prng::Rng;

const BATCH: usize = 4;

/// (id, n, h, rows, channels) per hosted model — distinct shapes and
/// nest configs so a routing slip cannot produce a plausible reply.
const ZOO: &[(&str, u8, u8, usize, usize)] = &[
    ("alpha", 8, 4, 96, 10),
    ("beta", 7, 3, 64, 12),
    ("gamma", 6, 2, 80, 8),
];

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_serving_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write the ZOO to `dir`; returns per-model (path, b_len).
fn write_zoo(dir: &std::path::Path) -> Vec<(std::path::PathBuf, u64)> {
    ZOO.iter()
        .map(|&(id, n, h, rows, channels)| {
            let c = container::synthetic_nest(0xA11CE + n as u64, n, h, rows, channels).unwrap();
            let path = dir.join(format!("{id}.nq"));
            let (_, _, b) = container::write(&path, &c).unwrap();
            (path, b)
        })
        .collect()
}

/// Deterministic probe images for one model.
fn images(seed: u64, image_len: usize, count: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| (0..image_len).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect()
}

/// Single-tenant baseline logits (row 0 of a padded batch) for every
/// image, computed through a private archive so the server's byte
/// accounting is untouched.
fn baseline(path: &std::path::Path, variant: Variant, imgs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let archive = Arc::new(NqArchive::open(path).unwrap());
    let budget = Arc::new(StoreBudget::new(u64::MAX));
    let mut t = NestTenant::from_archive("baseline", archive, budget, BATCH).unwrap();
    if variant == Variant::FullBit {
        t.switch(Decision::SwitchTo(Variant::FullBit)).unwrap().unwrap();
    }
    let (_, image_len, classes) = t.shape();
    imgs.iter()
        .map(|img| {
            assert_eq!(img.len(), image_len);
            let mut input = vec![0f32; BATCH * image_len];
            input[..image_len].copy_from_slice(img);
            t.run_batch(&input).unwrap()[..classes].to_vec()
        })
        .collect()
}

struct Hosted {
    ids: Vec<String>,
    archives: Vec<Arc<NqArchive>>,
    part: Vec<Vec<Vec<f32>>>,
    full: Vec<Vec<Vec<f32>>>,
    imgs: Vec<Vec<Vec<f32>>>,
    budget: Arc<StoreBudget>,
    handle: nestquant::coordinator::server::ServerHandle,
}

/// Build the zoo, compute baselines, and start a multi-tenant server
/// whose Section-B budget is `cap`.
fn start_zoo(tag: &str, cap: u64) -> Hosted {
    let dir = temp_dir(tag);
    let paths = write_zoo(&dir);
    let store = ModelStore::new();
    let budget = Arc::new(StoreBudget::new(cap));
    let tenants = nest_tenants_from_dir(&dir, &store, &budget, BATCH).unwrap();
    assert_eq!(tenants.len(), ZOO.len());

    let mut ids = Vec::new();
    let mut archives = Vec::new();
    let mut part = Vec::new();
    let mut full = Vec::new();
    let mut imgs = Vec::new();
    for ((id, t), (path, _)) in tenants.iter().zip(&paths) {
        // tenants come back sorted by file stem; map them to ZOO order
        let zoo_pos = ZOO.iter().position(|z| z.0 == id).unwrap();
        let (_, _, _, rows, _) = ZOO[zoo_pos];
        assert_eq!(t.shape().1, rows);
        let probe = images(0xBEEF + zoo_pos as u64, rows, 8);
        part.push(baseline(path, Variant::PartBit, &probe));
        full.push(baseline(path, Variant::FullBit, &probe));
        imgs.push(probe);
        ids.push(id.clone());
        archives.push(Arc::clone(t.archive()));
    }
    let boxed: Vec<(String, Box<dyn TenantExecutor>)> = tenants
        .into_iter()
        .map(|(id, t)| (id, Box::new(t) as Box<dyn TenantExecutor>))
        .collect();
    let handle = serve_tenants(
        boxed,
        ServerConfig { max_wait: Duration::from_millis(2), ..ServerConfig::default() },
    )
    .unwrap();
    Hosted { ids, archives, part, full, imgs, budget, handle }
}

/// ZOO is written with sorted ids, so tenant order == ZOO order.
#[test]
fn zoo_ids_are_sorted() {
    let mut sorted: Vec<&str> = ZOO.iter().map(|z| z.0).collect();
    sorted.sort_unstable();
    assert_eq!(sorted, ZOO.iter().map(|z| z.0).collect::<Vec<_>>());
}

/// Tentpole acceptance: concurrent clients against ≥ 3 hosted models,
/// a switch storm flipping every model mid-traffic, every reply equal
/// to a single-tenant baseline, ≥ 1 upgrade + 1 downgrade observed in
/// the replies of every model, zero section-A re-reads.
#[test]
fn replies_match_baselines_under_concurrent_switch_storm() {
    // generous budget: all three B sections fit — evictions are the
    // next test's job
    let z = start_zoo("storm", u64::MAX);
    let addr = z.handle.addr;
    let n_models = z.ids.len();

    let stop = Arc::new(AtomicBool::new(false));
    // per-model observed reply counts: [part, full]
    let seen: Arc<Vec<[AtomicU64; 2]>> =
        Arc::new((0..n_models).map(|_| [AtomicU64::new(0), AtomicU64::new(0)]).collect());

    let mut clients = Vec::new();
    for c in 0..6usize {
        let m = c % n_models;
        let id = z.ids[m].clone();
        let imgs = z.imgs[m].clone();
        let part = z.part[m].clone();
        let full = z.full[m].clone();
        let stop = Arc::clone(&stop);
        let seen = Arc::clone(&seen);
        clients.push(std::thread::spawn(move || -> usize {
            let mut client = Client::connect(addr).unwrap();
            let mut sent = 0usize;
            let mut i = c; // decorrelate clients on the same model
            while !stop.load(Ordering::Relaxed) && sent < 20_000 {
                let k = i % imgs.len();
                let logits = client.infer_model(&id, &imgs[k]).unwrap();
                if logits == part[k] {
                    seen[m][0].fetch_add(1, Ordering::Relaxed);
                } else if logits == full[k] {
                    seen[m][1].fetch_add(1, Ordering::Relaxed);
                } else {
                    panic!(
                        "{id}: torn reply — logits match neither baseline \
                         (img {k}, got {logits:?})"
                    );
                }
                sent += 1;
                i += 1;
            }
            sent
        }));
    }

    // switch storm: for each model, force ≥ 2 upgrades and ≥ 2
    // downgrades, each time waiting until the *replies* prove the new
    // variant was served mid-traffic (no sleep guessing)
    let wait_served = |m: usize, which: usize, before: u64| {
        let deadline = Instant::now() + Duration::from_secs(20);
        while seen[m][which].load(Ordering::Relaxed) <= before {
            assert!(
                Instant::now() < deadline,
                "model {m}: no {} reply observed after switch",
                if which == 0 { "part-bit" } else { "full-bit" }
            );
            std::thread::yield_now();
        }
    };
    for _round in 0..2 {
        for m in 0..n_models {
            let before_full = seen[m][1].load(Ordering::Relaxed);
            z.handle
                .advise(&z.ids[m], Decision::SwitchTo(Variant::FullBit))
                .unwrap();
            wait_served(m, 1, before_full);
            let before_part = seen[m][0].load(Ordering::Relaxed);
            z.handle
                .advise(&z.ids[m], Decision::SwitchTo(Variant::PartBit))
                .unwrap();
            wait_served(m, 0, before_part);
        }
    }
    stop.store(true, Ordering::Relaxed);
    let total: usize = clients.into_iter().map(|j| j.join().unwrap()).sum();
    assert!(total > 0);

    for (m, id) in z.ids.iter().enumerate() {
        // both variants actually served, mid-traffic, for every model
        assert!(seen[m][0].load(Ordering::Relaxed) >= 1, "{id}: no part-bit replies");
        assert!(seen[m][1].load(Ordering::Relaxed) >= 1, "{id}: no full-bit replies");
        let metrics = z.handle.metrics(id).unwrap();
        assert!(metrics.upgrades.load(Ordering::Relaxed) >= 2, "{id}");
        assert!(metrics.downgrades.load(Ordering::Relaxed) >= 2, "{id}");
        assert!(metrics.requests.load(Ordering::Relaxed) > 0, "{id}");
        // the zero-copy claims, per archive, across the whole storm
        let s = z.archives[m].stats();
        assert_eq!(s.a_fetches, 1, "{id}: section A re-read");
        assert_eq!(s.layout_parses, 1, "{id}: layout re-parsed");
        assert!(s.b_fetches >= 2, "{id}: expected one B fetch per upgrade");
        assert_eq!(s.b_fetches, s.b_releases + z.archives[m].b_resident() as u64, "{id}");
    }
    z.handle.stop();
}

/// Budget acceptance: a cap that holds only ONE model's Section B at a
/// time. Upgrading each model in turn evicts the previous one; a racing
/// sampler proves resident B bytes never exceed the cap — on the budget
/// ledger AND summed over the archives — while clients keep getting
/// baseline-exact replies throughout.
#[test]
fn shared_budget_evictions_stay_under_cap_mid_traffic() {
    let dir = temp_dir("budget_sizes");
    let paths = write_zoo(&dir);
    let b_sizes: Vec<u64> = paths.iter().map(|(_, b)| *b).collect();
    let cap = *b_sizes.iter().max().unwrap();
    // the cap admits any single B but never two of them
    let two_smallest: u64 = {
        let mut s = b_sizes.clone();
        s.sort_unstable();
        s[0] + s[1]
    };
    assert!(two_smallest > cap, "zoo sizes defeat the eviction scenario");

    let z = start_zoo("budget", cap);
    let addr = z.handle.addr;
    let n_models = z.ids.len();

    // racing sampler: the ceiling must hold at EVERY observable point
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let stop = Arc::clone(&stop);
        let budget = Arc::clone(&z.budget);
        let archives = z.archives.clone();
        std::thread::spawn(move || -> u64 {
            let mut samples = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let ledger = budget.resident_bytes();
                assert!(ledger <= budget.cap(), "budget ledger over cap: {ledger}");
                let by_archive: u64 = archives
                    .iter()
                    .map(|a| if a.b_resident() { a.section_b_bytes() } else { 0 })
                    .sum();
                assert!(
                    by_archive <= budget.cap(),
                    "archive-resident B over cap: {by_archive}"
                );
                samples += 1;
                std::thread::yield_now();
            }
            samples
        })
    };

    // light traffic on every model while the eviction storm runs
    let mut clients = Vec::new();
    for m in 0..n_models {
        let id = z.ids[m].clone();
        let imgs = z.imgs[m].clone();
        let part = z.part[m].clone();
        let full = z.full[m].clone();
        let stop = Arc::clone(&stop);
        clients.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) && i < 20_000 {
                let k = i % imgs.len();
                let logits = client.infer_model(&id, &imgs[k]).unwrap();
                assert!(
                    logits == part[k] || logits == full[k],
                    "{id}: reply matches neither baseline under eviction pressure"
                );
                i += 1;
            }
        }));
    }

    // eviction storm: each upgrade must evict the previous tenant's B
    for round in 0..3 {
        for m in 0..n_models {
            z.handle
                .advise(&z.ids[m], Decision::SwitchTo(Variant::FullBit))
                .unwrap();
            let resident: Vec<bool> = z.archives.iter().map(|a| a.b_resident()).collect();
            assert!(resident[m], "round {round}: upgraded model must hold B");
            assert_eq!(
                resident.iter().filter(|r| **r).count(),
                1,
                "round {round}: cap admits exactly one resident B"
            );
        }
    }
    assert!(
        z.budget.evictions() >= (3 * n_models - 1) as u64,
        "every upgrade after the first must evict: {}",
        z.budget.evictions()
    );
    // let traffic keep flowing over the post-eviction state (forced
    // downgrades reconcile at batch time) and the sampler accumulate
    std::thread::sleep(Duration::from_millis(150));

    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap();
    }
    let samples = sampler.join().unwrap();
    assert!(samples > 100, "sampler barely ran ({samples} samples)");

    // eviction pressure still never touched section A
    for (m, id) in z.ids.iter().enumerate() {
        let s = z.archives[m].stats();
        assert_eq!(s.a_fetches, 1, "{id}");
        assert_eq!(s.layout_parses, 1, "{id}");
    }
    let events = z.budget.drain_events();
    assert!(
        events
            .iter()
            .any(|e| matches!(e, nestquant::store::BudgetEvent::Evicted { .. })),
        "eviction trace must record victims"
    );
    z.handle.stop();
}

/// Telemetry satellite: scrape the `metrics` wire command mid-run and
/// hold it to exact account. Per-tenant scraped values equal the
/// server-side `Metrics` atomics; the switch byte accounting equals the
/// archives' own `ArchiveStats`; and the scraped snapshot renders valid
/// Prometheus exposition (the `--prom` CLI path uses this rendering of
/// this JSON, so the surfaces cannot disagree).
#[test]
fn metrics_wire_scrape_agrees_with_archive_stats() {
    let z = start_zoo("metrics", u64::MAX);
    let mut client = Client::connect(z.handle.addr).unwrap();

    // scripted traffic: 4 sequential part-bit requests per model, then
    // one upgrade + one downgrade each
    for (m, id) in z.ids.iter().enumerate() {
        for k in 0..4 {
            let logits = client.infer_model(id, &z.imgs[m][k]).unwrap();
            assert_eq!(logits, z.part[m][k]);
        }
        z.handle.advise(id, Decision::SwitchTo(Variant::FullBit)).unwrap();
        z.handle.advise(id, Decision::SwitchTo(Variant::PartBit)).unwrap();
    }

    let json = client.metrics().unwrap();
    let snap = Snapshot::from_json(&json).unwrap();

    for (m, id) in z.ids.iter().enumerate() {
        let t = snap.tenant(id).unwrap_or_else(|| panic!("{id} missing from snapshot"));
        // the scrape quiesced (no in-flight traffic): scraped values ARE
        // the server-side atomics, exactly
        let metrics = z.handle.metrics(id).unwrap();
        assert_eq!(t.requests, metrics.requests.load(Ordering::Relaxed), "{id}");
        assert_eq!(t.upgrades, metrics.upgrades.load(Ordering::Relaxed), "{id}");
        assert_eq!(t.downgrades, metrics.downgrades.load(Ordering::Relaxed), "{id}");
        assert_eq!(
            t.page_in_bytes,
            metrics.page_in_bytes.load(Ordering::Relaxed),
            "{id}"
        );
        assert_eq!(t.requests, 4, "{id}: exactly this test's traffic");
        assert_eq!((t.upgrades, t.downgrades), (1, 1), "{id}");

        // byte accounting vs ArchiveStats: the tenant launched part-bit,
        // so its one upgrade fetched section B exactly once — the
        // snapshot's switch bytes must equal the archive's fetched bytes
        let s = z.archives[m].stats();
        let b_len = z.archives[m].section_b_bytes();
        assert_eq!(s.b_fetches, 1, "{id}");
        assert_eq!(t.page_in_bytes, s.b_bytes_fetched, "{id}: page-in == B fetched");
        assert_eq!(t.page_in_bytes, b_len, "{id}");
        assert_eq!(t.page_out_bytes, b_len, "{id}: downgrade paged B back out");
        assert!(t.request_max_us > 0, "{id}: latency histogram recorded");
    }

    // global counters include this test's contribution (other tests in
    // this binary may add to them concurrently, so >= not ==)
    let n = z.ids.len() as u64;
    let c = |name: &str| snap.counter(name).unwrap_or_else(|| panic!("missing {name}"));
    assert!(c("nq_serving_requests") >= 4 * n, "{}", c("nq_serving_requests"));
    assert!(c("nq_serving_upgrades") >= n);
    assert!(c("nq_serving_downgrades") >= n);
    assert!(c("nq_store_b_fetches") >= n);
    assert_eq!(snap.histogram("nq_serving_request_latency").map(|h| h.count >= 4 * n), Some(true));

    // the CLI's --prom rendering of exactly this JSON passes the grammar
    validate_prometheus(&snap.prometheus()).unwrap();
    z.handle.stop();
}

/// An upgrade whose Section B alone exceeds the shared cap is rejected
/// cleanly (no eviction, no partial state) and the tenant keeps serving
/// part-bit.
#[test]
fn oversized_upgrade_is_rejected_and_tenant_keeps_serving() {
    let z = start_zoo("oversize", 16); // cap far below any B section
    let err = z
        .handle
        .advise(&z.ids[0], Decision::SwitchTo(Variant::FullBit))
        .unwrap_err();
    assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
    let mut client = Client::connect(z.handle.addr).unwrap();
    let logits = client.infer_model(&z.ids[0], &z.imgs[0][0]).unwrap();
    assert_eq!(logits, z.part[0][0], "tenant still serves part-bit");
    assert_eq!(z.budget.evictions(), 0);
    z.handle.stop();
}

/// Router behaviour: `models` lists every hosted id; unknown ids and
/// ambiguous empty ids are clean errors that leave the connection
/// usable; wrong-size images are rejected per-tenant.
#[test]
fn models_listing_and_routing_errors() {
    let z = start_zoo("routing", u64::MAX);
    let mut client = Client::connect(z.handle.addr).unwrap();
    assert_eq!(client.models().unwrap(), z.ids);
    assert_eq!(z.handle.models(), z.ids);

    let err = client.infer_model("ghost", &z.imgs[0][0]).unwrap_err();
    assert!(format!("{err}").contains("unknown model"), "{err}");
    // empty id is ambiguous with 3 tenants
    let err = client.infer(&z.imgs[0][0]).unwrap_err();
    assert!(format!("{err}").contains("model id required"), "{err}");
    // wrong image size for THIS tenant (beta's image_len ≠ alpha's)
    let err = client.infer_model(&z.ids[0], &z.imgs[1][0]).unwrap_err();
    assert!(format!("{err}").contains("bad image size"), "{err}");
    // connection still usable after every error
    let logits = client.infer_model(&z.ids[2], &z.imgs[2][0]).unwrap();
    assert_eq!(logits, z.part[2][0]);
    z.handle.stop();
}

/// Count this process's live threads (linux procfs; the CI target).
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").unwrap().count()
}

/// Satellite: deterministic shutdown. Repeated start/stop cycles across
/// all three stop paths (handle stop, client `stop` frame then handle
/// join, stop with an idle client connected) complete quickly and do
/// not leak threads — this hung or leaked before the accept-loop
/// re-check + tracked handler joins.
#[test]
fn repeated_start_stop_never_hangs_or_leaks_threads() {
    let dir = temp_dir("stoploop");
    let c = container::synthetic_nest(7, 8, 4, 32, 6).unwrap();
    let path = dir.join("m.nq");
    container::write(&path, &c).unwrap();

    #[cfg(target_os = "linux")]
    let threads_before = thread_count();

    let t0 = Instant::now();
    for cycle in 0..12 {
        let archive = Arc::new(NqArchive::open(&path).unwrap());
        let budget = Arc::new(StoreBudget::new(u64::MAX));
        let tenant = NestTenant::from_archive("m", archive, budget, 2).unwrap();
        let handle = serve_tenants(
            vec![("m".to_string(), Box::new(tenant) as Box<dyn TenantExecutor>)],
            ServerConfig::default(),
        )
        .unwrap();
        let mut client = Client::connect(handle.addr).unwrap();
        let img = vec![0.5f32; 32];
        client.infer_model("m", &img).unwrap();
        match cycle % 3 {
            0 => handle.stop(),
            1 => {
                // a bare stop frame must flag the server down on its
                // own (handler pokes the acceptor); stop() then only
                // joins what is already shutting down
                client.stop_server().unwrap();
                let deadline = Instant::now() + Duration::from_secs(5);
                while !handle.stopped() {
                    assert!(Instant::now() < deadline, "stop frame ignored");
                    std::thread::yield_now();
                }
                handle.stop();
            }
            _ => {
                // an extra idle connection must not block shutdown
                let _idle = Client::connect(handle.addr).unwrap();
                handle.stop();
            }
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "12 start/stop cycles took {:?}",
        t0.elapsed()
    );

    #[cfg(target_os = "linux")]
    {
        // every server thread joined. The slack absorbs concurrently
        // running sibling tests (test harness + their servers) under a
        // parallel `cargo test`; a real leak here is ~4 threads/cycle
        // (~48), far beyond it. The CI serving leg runs single-threaded,
        // where the count is near-exact.
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            let now = thread_count();
            if now <= threads_before + 16 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "leaked threads: {threads_before} before, {now} after"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

/// The `ModelManager` side of the shared budget: two managers under one
/// cap evict each other's Section B on upgrade, with the ledgers and
/// `ArchiveStats` agreeing. (Fallback engine only: no PJRT needed —
/// switching never executes a graph.)
#[cfg(not(feature = "pjrt"))]
#[test]
fn model_managers_share_one_section_b_budget() {
    use nestquant::coordinator::ModelManager;
    use nestquant::device::MemoryLedger;
    use nestquant::runtime::{Engine, ModelSpec, ParamSpec};
    use std::collections::BTreeMap;

    let dir = temp_dir("mgr_budget");
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();
    let mut managers = Vec::new();
    let mut b_len = 0;
    for (i, name) in ["m0", "m1"].iter().enumerate() {
        let c = container::synthetic_nest(40 + i as u64, 8, 4, 64, 8).unwrap();
        let (_, _, b) = container::write(&dir.join(format!("{name}.nq")), &c).unwrap();
        b_len = b;
        let spec = ModelSpec {
            name: (*name).to_string(),
            params: vec![
                ParamSpec { name: "layer.w".into(), shape: vec![64, 8], quantized: true },
                ParamSpec { name: "layer.b".into(), shape: vec![8], quantized: false },
            ],
            hlo: BTreeMap::from([(8u8, "toy.hlo.txt".to_string())]),
            nest_containers: BTreeMap::from([("8|4".to_string(), format!("{name}.nq"))]),
            mono_containers: BTreeMap::new(),
            fp32_container: String::new(),
            expected: BTreeMap::new(),
        };
        let engine = Engine::cpu().unwrap();
        managers.push(ModelManager::new(&engine, spec, 8, &dir, &format!("{name}.nq")).unwrap());
    }
    // room for exactly one resident Section B
    let budget = Arc::new(StoreBudget::new(b_len));
    for (i, m) in managers.iter_mut().enumerate() {
        m.set_store_budget(format!("m{i}"), Arc::clone(&budget));
    }
    let mut ledger = MemoryLedger::new(1 << 30);
    managers[0].load_part_bit(&mut ledger).unwrap();
    managers[1].load_part_bit(&mut ledger).unwrap();

    managers[0].upgrade(&mut ledger).unwrap();
    assert!(managers[0].archive().b_resident());
    // m1's upgrade evicts m0's B under the shared cap
    managers[1].upgrade(&mut ledger).unwrap();
    assert!(managers[1].archive().b_resident());
    assert!(!managers[0].archive().b_resident(), "m0 evicted");
    assert_eq!(budget.resident_bytes(), b_len);
    assert_eq!(budget.evictions(), 1);
    assert_eq!(managers[0].archive().stats().b_releases, 1);

    // m0's downgrade after eviction is a no-op on the budget ledger but
    // still a valid state transition (its weights were never torn)
    managers[0].downgrade(&mut ledger).unwrap();
    assert_eq!(budget.resident_bytes(), b_len);
    // m1 downgrades voluntarily → ledger empties
    managers[1].downgrade(&mut ledger).unwrap();
    assert_eq!(budget.resident_bytes(), 0);
    // zero section-A re-reads on either manager throughout
    for m in &managers {
        assert_eq!(m.archive().stats().a_fetches, 1);
        assert_eq!(m.archive().stats().layout_parses, 1);
    }
}
