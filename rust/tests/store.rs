//! Store-layer integration tests — artifact-independent.
//!
//! Two claims are proven here rather than asserted in comments:
//!
//! 1. **Geometry** (property test): for every legal (n, h) synthetic
//!    container, the `SectionIndex` ranges reassemble bit-identically
//!    (`A ++ B == whole file`), and a `PartBitModel` view over the
//!    section-A bytes decodes equal to the legacy
//!    `container::parse(..., part_bit_only)` path.
//! 2. **Zero-copy switching** (byte accounting): the coordinator's
//!    upgrade/downgrade path performs zero full-container re-parses and
//!    zero section-A re-reads — `ArchiveStats` counts them.

use nestquant::container::{self, TensorData};
use nestquant::store::{FileSource, MmapSource, NqArchive, PayloadView, Section, SectionSource};
use nestquant::util::propcheck;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("nq_store_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// All legal packable nest combinations: 2 <= h < n <= 16.
fn grid() -> impl Iterator<Item = (u8, u8)> {
    (3..=16u8).flat_map(|n| (2..n).map(move |h| (n, h)))
}

/// Satellite: `SectionIndex` ranges reassemble bit-identically across
/// the whole (n, h) grid, with randomized tensor dims per combination.
#[test]
fn section_ranges_reassemble_bit_identically_across_grid() {
    for (n, h) in grid() {
        propcheck::check(
            &format!("store-reassemble-n{n}-h{h}"),
            3,
            |rng, scale| {
                let rows = ((48.0 * scale) as usize).max(2) + rng.index(16);
                let channels = 1 + rng.index(8);
                (rows, channels)
            },
            |&(rows, channels)| {
                let seed = u64::from(n) * 1000 + u64::from(h) * 10 + rows as u64;
                let c = container::synthetic_nest(seed, n, h, rows, channels).unwrap();
                let bytes = container::serialize(&c).unwrap();
                let arch = NqArchive::from_bytes(&bytes).unwrap();
                let idx = arch.index();
                let (ra, rb) = (idx.section_a(), idx.section_b());
                // contiguous ranges exhausting the payload (the
                // integrity trailer rides after section B)
                if ra.start != 0 || ra.end != rb.start || rb.end != idx.payload_len() {
                    return false;
                }
                if idx.file_len as usize != bytes.len() || idx.checksums.is_none() {
                    return false;
                }
                // A ++ B is the payload, bit for bit (checksum-verified
                // on fetch by the archive)
                let a = arch.ensure_a().unwrap();
                let b = arch.attach_b().unwrap();
                let mut whole = a.to_vec();
                whole.extend_from_slice(&b);
                whole[..] == bytes[..idx.payload_len() as usize]
            },
        );
    }
}

/// Satellite: a `PartBitModel` view over the section-A bytes decodes
/// equal to the legacy `parse(..., part_bit_only)` across the grid.
#[test]
#[allow(deprecated)] // the comparison target IS the legacy API
fn part_bit_view_equals_legacy_part_parse_across_grid() {
    for (n, h) in grid() {
        let seed = u64::from(n) * 131 + u64::from(h);
        let c = container::synthetic_nest(seed, n, h, 24, 4).unwrap();
        let bytes = container::serialize(&c).unwrap();

        // legacy: typed parse stopping at section A
        let legacy = container::parse(&bytes, true).unwrap();
        // store: typed view over the A bytes only (A-only archive)
        let idx_end = legacy.section_a_bytes() as usize;
        let arch = NqArchive::from_bytes(&bytes[..idx_end]).unwrap();
        let part = arch.part_bit().unwrap();

        assert_eq!(part.layout().n(), legacy.n, "INT({n}|{h})");
        assert_eq!(part.layout().h(), legacy.h, "INT({n}|{h})");
        assert_eq!(part.layout().name(), legacy.name);
        assert_eq!(part.len(), legacy.tensors.len());
        for (view, t) in part.tensors().zip(&legacy.tensors) {
            assert_eq!(view.name(), t.name);
            assert_eq!(view.shape(), &t.shape[..]);
            match (view.payload(), &t.data) {
                (
                    PayloadView::Nest { scales, w_high, w_low },
                    TensorData::Nest {
                        scales: s2,
                        w_high: h2,
                        w_low: l2,
                    },
                ) => {
                    assert!(w_low.is_none() && l2.is_none(), "part-bit has no w_low");
                    assert_eq!(scales.to_vec(), *s2, "INT({n}|{h}) {}", t.name);
                    assert_eq!(w_high.bits(), h2.bits());
                    assert_eq!(w_high.unpack(), h2.unpack(), "INT({n}|{h}) {}", t.name);
                }
                (PayloadView::Fp32(v), TensorData::Fp32(f)) => {
                    assert_eq!(v.to_vec(), *f);
                }
                _ => panic!("INT({n}|{h}): payload kind mismatch for {}", t.name),
            }
        }
        // full-bit must be cleanly unavailable from an A-only source
        assert!(arch.full_bit().is_err());
    }
}

/// File-backed sources agree with in-memory ones (positioned reads).
#[test]
fn file_source_round_trips_sections() {
    let dir = temp_dir("filesrc");
    let path = dir.join("m.nq");
    let c = container::synthetic_nest(9, 8, 4, 64, 8).unwrap();
    let (total, a_len, b_len) = container::write(&path, &c).unwrap();
    let src = FileSource::new(&path);
    let idx = src.index().unwrap();
    assert_eq!(idx.file_len, total);
    assert_eq!(idx.section_a_bytes(), a_len);
    assert_eq!(idx.section_b_bytes(), b_len);
    let whole = std::fs::read(&path).unwrap();
    let a = src.fetch(Section::A).unwrap();
    let b = src.fetch(Section::B).unwrap();
    assert_eq!(&whole[..a.len()], &a[..]);
    assert_eq!(&whole[a.len()..a.len() + b.len()], &b[..]);
    // the trailer is the only remainder
    assert_eq!(whole.len(), a.len() + b.len() + container::TRAILER_LEN);
}

/// Tentpole: `MmapSource` is byte-identical to `FileSource` across
/// every legal (n, h) combination — index and both sections. Odd
/// element counts force padded final words in the packed streams, the
/// historical corruption spot for length math.
#[test]
fn mmap_source_matches_file_source_across_grid() {
    let dir = temp_dir("mmap_grid");
    for (n, h) in grid() {
        let seed = u64::from(n) * 977 + u64::from(h);
        // 17 rows x 3 channels: odd counts ⇒ padded final packed words
        let c = container::synthetic_nest(seed, n, h, 17, 3).unwrap();
        let path = dir.join(format!("g_{n}_{h}.nq"));
        container::write(&path, &c).unwrap();

        let file = FileSource::new(&path);
        let mapped = MmapSource::new(&path);
        assert_eq!(
            file.index().unwrap(),
            mapped.index().unwrap(),
            "INT({n}|{h}) index"
        );
        for section in [Section::A, Section::B] {
            let f = file.fetch(section).unwrap();
            let m = mapped.fetch(section).unwrap();
            assert_eq!(&f[..], &m[..], "INT({n}|{h}) {section} bytes");
        }
    }
}

/// Tentpole: lazy CRC catches a tampered Section B on its *first
/// touch* — and keeps failing from the memoized verdict — while the
/// untampered Section A keeps serving the part-bit model throughout.
#[test]
fn tampered_section_b_fails_first_touch_while_a_serves() {
    let dir = temp_dir("tamper_b");
    let path = dir.join("t.nq");
    let c = container::synthetic_nest(23, 8, 4, 64, 8).unwrap();
    let (_, a_len, b_len) = container::write(&path, &c).unwrap();
    assert!(b_len > 0);

    // flip one byte in the middle of Section B on disk, before open
    let mut bytes = std::fs::read(&path).unwrap();
    let victim = (a_len + b_len / 2) as usize;
    bytes[victim] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let arch = NqArchive::open(&path).unwrap();
    // Section A is untouched: launch path serves normally
    let a = arch.ensure_a().unwrap();
    assert_eq!(a.len() as u64, a_len);
    arch.part_bit().unwrap();

    // first touch of B detects the corruption…
    let err = arch.attach_b().unwrap_err().to_string();
    assert!(
        err.contains("section B checksum mismatch"),
        "unexpected error: {err}"
    );
    // …and the memoized verdict keeps failing without a fresh verify
    let err2 = arch.attach_b().unwrap_err().to_string();
    assert!(err2.contains("section B checksum mismatch"));

    // A still serves after the B failures
    let a2 = arch.ensure_a().unwrap();
    assert_eq!(a2.len() as u64, a_len);
    let s = arch.stats();
    assert_eq!(s.a_fetches, 1, "A fetched once, cached thereafter");
    assert_eq!(s.b_fetches, 0, "a corrupt B never counts as fetched");
}

/// Acceptance: opening a zoo is O(1) per archive — 200 archives opened
/// (header probe + layout index only) with **zero** section fetches,
/// proven by `ArchiveStats`. This is what makes 1000-archive zoos
/// startable: section bytes move only when a device first asks.
#[test]
fn zoo_open_performs_zero_eager_section_reads() {
    let dir = temp_dir("o1_open");
    const ZOO: usize = 200;
    for i in 0..ZOO {
        let c = container::synthetic_nest(3000 + i as u64, 8, 4, 32, 8).unwrap();
        container::write(&dir.join(format!("z{i:03}.nq")), &c).unwrap();
    }

    let mut archives = Vec::with_capacity(ZOO);
    for i in 0..ZOO {
        let arch = NqArchive::open(dir.join(format!("z{i:03}.nq"))).unwrap();
        // the index is available (the probe ran)…
        assert!(arch.index().section_a_bytes() > 0);
        archives.push(arch);
    }
    for arch in &archives {
        let s = arch.stats();
        assert_eq!(s.a_fetches, 0, "open must not fetch section A");
        assert_eq!(s.b_fetches, 0, "open must not fetch section B");
        assert_eq!(s.a_bytes_fetched + s.b_bytes_fetched, 0);
    }

    // and a single archive still serves on demand afterwards
    let first = &archives[0];
    first.ensure_a().unwrap();
    assert_eq!(first.stats().a_fetches, 1);
}

/// Acceptance: the coordinator upgrade/downgrade path does zero
/// full-container re-parses and zero section-A re-reads — proven by the
/// archive's byte accounting under the real `ModelManager`.
///
/// (Fallback engine only: under `pjrt` the toy HLO would be compiled.)
#[cfg(not(feature = "pjrt"))]
#[test]
fn manager_switching_accounts_zero_a_rereads_and_zero_reparses() {
    use nestquant::coordinator::ModelManager;
    use nestquant::device::MemoryLedger;
    use nestquant::runtime::{Engine, ModelSpec, ParamSpec};
    use std::collections::BTreeMap;

    let dir = temp_dir("manager");
    let c = container::synthetic_nest(17, 8, 4, 96, 16).unwrap();
    let (_, a_len, b_len) = container::write(&dir.join("m.nq"), &c).unwrap();
    std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy\n").unwrap();

    let spec = ModelSpec {
        name: "toy".into(),
        params: vec![
            ParamSpec {
                name: "layer.w".into(),
                shape: vec![96, 16],
                quantized: true,
            },
            ParamSpec {
                name: "layer.b".into(),
                shape: vec![16],
                quantized: false,
            },
        ],
        hlo: BTreeMap::from([(8u8, "toy.hlo.txt".to_string())]),
        nest_containers: BTreeMap::from([("8|4".to_string(), "m.nq".to_string())]),
        mono_containers: BTreeMap::new(),
        fp32_container: String::new(),
        expected: BTreeMap::new(),
    };
    let engine = Engine::cpu().unwrap();
    let mut mgr = ModelManager::new(&engine, spec, 8, &dir, "m.nq").unwrap();
    assert_eq!(mgr.section_bytes(), (a_len, b_len));
    // construction is a header probe: no payload bytes moved yet
    assert_eq!(mgr.archive().stats().a_fetches, 0);

    let mut ledger = MemoryLedger::new(1 << 30);
    mgr.load_part_bit(&mut ledger).unwrap();
    assert_eq!(ledger.used(), a_len);

    const CYCLES: u64 = 4;
    for _ in 0..CYCLES {
        let up = mgr.upgrade(&mut ledger).unwrap();
        assert_eq!(up.page_in_bytes, b_len);
        assert_eq!(up.page_out_bytes, 0, "upgrade has zero page-out");
        assert_eq!(ledger.used(), a_len + b_len);
        let down = mgr.downgrade(&mut ledger).unwrap();
        assert_eq!(down.page_in_bytes, 0, "downgrade has zero page-in");
        assert_eq!(down.page_out_bytes, b_len);
        assert_eq!(ledger.used(), a_len);
    }

    let s = mgr.archive().stats();
    assert_eq!(s.a_fetches, 1, "section A read exactly once, ever");
    assert_eq!(s.layout_parses, 1, "container parsed exactly once, ever");
    assert_eq!(s.a_bytes_fetched, a_len);
    assert_eq!(s.b_fetches, CYCLES, "one B fetch per upgrade");
    assert_eq!(s.b_bytes_fetched, CYCLES * b_len);
    assert_eq!(s.b_releases, CYCLES);

    // unload drops bytes but keeps the parsed layout; a re-launch
    // re-fetches A without re-parsing
    mgr.unload(&mut ledger).unwrap();
    assert_eq!(ledger.used(), 0);
    mgr.load_part_bit(&mut ledger).unwrap();
    let s = mgr.archive().stats();
    assert_eq!(s.a_fetches, 2);
    assert_eq!(s.layout_parses, 1, "unload/reload never re-parses");
}
