//! Telemetry integration tests: the process-global registry observed
//! end-to-end, with exact arithmetic instead of "probably moved".
//!
//! What is proven here:
//!
//! 1. **Scripted-cycle exactness**: a launch → upgrade → evict →
//!    downgrade → unload cycle over synthetic archives and a
//!    `StoreBudget` moves *exactly* the predicted counter deltas, and
//!    the resident-bytes gauges balance back to their prior level.
//! 2. **Race-free recording**: N threads hammering one counter, gauge,
//!    histogram, and kernel cell land exact totals — on private
//!    instances and on the global registry alike.
//! 3. **Three-surface identity**: the JSON wire snapshot parses back
//!    byte-identically, and the Prometheus / `top` renderings of the
//!    parsed copy equal those of the original — one gathered truth.
//! 4. **Prometheus grammar**: a real gathered snapshot (tenants, trace
//!    and all) passes the text-exposition validator.
//! 5. **Zero-cost-when-disabled tracing**: `nq_trace!` never evaluates
//!    its format arguments while the ring is disabled.
//!
//! The registry is process-global, so tests that assert exact *deltas*
//! on it serialize behind one mutex; everything else runs in parallel
//! and only ever asserts on values it gathered itself.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use nestquant::container;
use nestquant::faults::{self, FaultMode, FaultSpec};
use nestquant::nq_trace;
use nestquant::reactor::{Admit, FairScheduler};
use nestquant::store::{NqArchive, StoreBudget};
use nestquant::telemetry::{
    registry, validate_prometheus, Counter, Gauge, LatencyHisto, Metrics, OP_UNPACK_INTS,
    Snapshot, TraceKind,
};

/// Serializes the registry-delta tests (the registry is shared by every
/// test thread in this binary).
static SEQ: Mutex<()> = Mutex::new(());

fn seq() -> MutexGuard<'static, ()> {
    SEQ.lock().unwrap_or_else(|e| e.into_inner())
}

fn archive(seed: u64) -> Arc<NqArchive> {
    let c = container::synthetic_nest(seed, 8, 4, 64, 8).unwrap();
    Arc::new(NqArchive::from_container(&c).unwrap())
}

/// The ISSUE's scripted cycle: launch (section-A page-in), budgeted
/// upgrades, an LRU eviction, voluntary downgrades, and a full unload —
/// every registry delta predicted exactly, gauges balanced.
#[test]
fn scripted_cycle_moves_exact_counter_deltas() {
    let _g = seq();
    let before = Snapshot::gather(&[]);

    let arcs: Vec<Arc<NqArchive>> = (0..3).map(|i| archive(0x7E1E + i)).collect();
    let a_len = arcs[0].section_a_bytes();
    let b_len = arcs[0].section_b_bytes();
    assert!(arcs.iter().all(|a| a.section_b_bytes() == b_len));

    // launch: archive 0 pages section A in once; the second view is a
    // cache hit and must not move any counter
    arcs[0].part_bit().unwrap();
    arcs[0].part_bit().unwrap();

    // upgrades under a two-section budget, then a third attach that
    // must evict the LRU victim
    let budget = StoreBudget::new(2 * b_len);
    budget.attach_b("m0", &arcs[0]).unwrap();
    budget.attach_b("m1", &arcs[1]).unwrap();
    budget.touch("m0"); // m1 becomes LRU
    let evicted = budget.attach_b("m2", &arcs[2]).unwrap();
    assert_eq!(evicted, vec!["m1".to_string()]);

    // voluntary downgrades + full unload
    assert!(budget.release_b("m0"));
    assert!(budget.release_b("m2"));
    assert!(arcs[0].release_a());

    let after = Snapshot::gather(&[]);
    let d = |name: &str| {
        after.counter(name).unwrap_or_else(|| panic!("missing counter {name}"))
            - before.counter(name).unwrap_or_else(|| panic!("missing counter {name}"))
    };
    assert_eq!(d("nq_store_archive_opens"), 3);
    assert_eq!(d("nq_store_a_fetches"), 1, "section A crossed exactly once");
    assert_eq!(d("nq_store_a_bytes_fetched"), a_len);
    assert_eq!(d("nq_store_b_fetches"), 3, "one B fetch per budgeted attach");
    assert_eq!(d("nq_store_b_bytes_fetched"), 3 * b_len);
    assert_eq!(d("nq_store_evictions"), 1);
    assert_eq!(d("nq_store_evicted_bytes"), b_len);
    // releases: the eviction of m1 plus the two voluntary downgrades
    assert_eq!(d("nq_store_b_releases"), 3);
    assert_eq!(d("nq_store_crc_failures"), 0);
    // the gauges went up and came all the way back down
    assert_eq!(
        after.gauge("nq_store_resident_a_bytes"),
        before.gauge("nq_store_resident_a_bytes"),
        "resident-A gauge must balance after unload"
    );
    assert_eq!(
        after.gauge("nq_store_resident_b_bytes"),
        before.gauge("nq_store_resident_b_bytes"),
        "resident-B gauge must balance after releases"
    );
}

/// N threads hammer private primitives: totals are exact, not
/// approximate — relaxed atomics lose no increments.
#[test]
fn concurrent_recording_totals_are_exact() {
    const THREADS: u64 = 8;
    const PER: u64 = 10_000;
    let c = Counter::new();
    let g = Gauge::new();
    let h = LatencyHisto::new();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let (c, g, h) = (&c, &g, &h);
            s.spawn(move || {
                for i in 0..PER {
                    c.inc();
                    g.add(2);
                    g.sub(1);
                    h.record(Duration::from_micros(1 + (t * PER + i) % 512));
                }
            });
        }
    });
    assert_eq!(c.get(), THREADS * PER);
    assert_eq!(g.get(), THREADS * PER);
    assert_eq!(h.count(), THREADS * PER);
    assert!(h.mean_us() > 0.0);
    assert!(h.max_us() <= 512);
}

/// The same exactness on the global registry, including the two-atomic
/// kernel hot-path record.
#[test]
fn global_registry_concurrent_deltas_are_exact() {
    let _g = seq();
    const THREADS: u64 = 8;
    const PER: u64 = 5_000;
    let r = registry();
    let before_calls = r.kernels.calls(OP_UNPACK_INTS, 0);
    let before_bytes = r.kernels.bytes(OP_UNPACK_INTS, 0);
    let before_chunks = Snapshot::gather(&[]).counter("nq_fleet_chunks_sent").unwrap();
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for _ in 0..PER {
                    r.kernels.record(OP_UNPACK_INTS, 0, 64);
                    r.fleet.chunks_sent.inc();
                }
            });
        }
    });
    assert_eq!(r.kernels.calls(OP_UNPACK_INTS, 0), before_calls + THREADS * PER);
    assert_eq!(
        r.kernels.bytes(OP_UNPACK_INTS, 0),
        before_bytes + THREADS * PER * 64
    );
    let after = Snapshot::gather(&[]);
    assert_eq!(
        after.counter("nq_fleet_chunks_sent").unwrap(),
        before_chunks + THREADS * PER,
        "snapshot sees the exact global delta"
    );
    // and the per-op/tier cell surfaced under its canonical name
    assert!(
        after.counter("nq_kernel_unpack_ints_scalar_calls").unwrap()
            >= before_calls + THREADS * PER
    );
}

/// One gathered truth, three renderings: JSON roundtrip is
/// byte-identical and the prometheus/top renderings of the parsed copy
/// equal the original's.
#[test]
fn three_surfaces_report_identical_totals() {
    let m = Arc::new(Metrics::default());
    m.requests.fetch_add(11, Ordering::Relaxed);
    m.batches.fetch_add(3, Ordering::Relaxed);
    m.batch_occupancy_sum.fetch_add(11, Ordering::Relaxed);
    m.upgrades.fetch_add(2, Ordering::Relaxed);
    m.downgrades.fetch_add(2, Ordering::Relaxed);
    m.page_in_bytes.fetch_add(8192, Ordering::Relaxed);
    m.page_out_bytes.fetch_add(8192, Ordering::Relaxed);
    for us in [90u64, 180, 360, 720, 1440] {
        m.request_latency.record(Duration::from_micros(us));
    }
    m.switch_latency.record(Duration::from_micros(250));
    let tenants = vec![("alpha".to_string(), Arc::clone(&m))];

    let snap = Snapshot::gather(&tenants);
    let json = snap.to_json();
    let parsed = Snapshot::from_json(&json).unwrap();
    assert_eq!(parsed, snap, "wire roundtrip is lossless");
    assert_eq!(parsed.to_json(), json, "re-serialization is byte-identical");
    assert_eq!(parsed.prometheus(), snap.prometheus());
    assert_eq!(parsed.top_table(), snap.top_table());

    // the scraped tenant numbers ARE the source atomics
    let t = parsed.tenant("alpha").unwrap();
    assert_eq!(t.requests, 11);
    assert_eq!(t.upgrades, 2);
    assert_eq!(t.page_in_bytes, 8192);
    assert_eq!(t.request_max_us, 1440);

    // and all three surfaces carry the same totals
    let prom = parsed.prometheus();
    assert!(prom.contains("nq_tenant_requests{tenant=\"alpha\"} 11"));
    assert!(prom.contains("nq_tenant_page_in_bytes{tenant=\"alpha\"} 8192"));
    let top = parsed.top_table();
    assert!(top.contains("alpha"), "{top}");
}

/// A real gathered snapshot — global counters, gauges, histograms,
/// labelled tenants — renders valid Prometheus text exposition.
#[test]
fn gathered_prometheus_passes_grammar() {
    let m = Arc::new(Metrics::default());
    m.requests.fetch_add(5, Ordering::Relaxed);
    m.request_latency.record(Duration::from_micros(400));
    let tenants = vec![
        ("quoted\"tenant".to_string(), Arc::clone(&m)),
        ("plain".to_string(), Arc::default()),
    ];
    let snap = Snapshot::gather(&tenants);
    let prom = snap.prometheus();
    validate_prometheus(&prom).unwrap();
    // label escaping survived the grammar check
    assert!(prom.contains("tenant=\"quoted\\\"tenant\""));
}

/// The disabled-path guarantee: `nq_trace!` must not evaluate its
/// format arguments (let alone allocate) while the ring is off.
#[test]
fn disabled_trace_never_evaluates_format_args() {
    let _g = seq();
    registry().trace.disable();
    registry().trace.clear();
    let evaluated = AtomicU64::new(0);
    nq_trace!(TraceKind::Switch, "{}", {
        evaluated.fetch_add(1, Ordering::Relaxed);
        "side effect"
    });
    assert_eq!(evaluated.load(Ordering::Relaxed), 0, "args built while disabled");
    assert_eq!(registry().trace.len(), 0);

    registry().trace.enable();
    nq_trace!(TraceKind::Switch, "{}", {
        evaluated.fetch_add(1, Ordering::Relaxed);
        "recorded"
    });
    registry().trace.disable();
    assert_eq!(evaluated.load(Ordering::Relaxed), 1);
    let tail = registry().trace.tail(1);
    assert_eq!(tail.len(), 1);
    assert_eq!(tail[0].kind, TraceKind::Switch);
    assert_eq!(tail[0].detail, "recorded");
    registry().trace.clear();
}

/// Fault-layer counters move exactly: every armed fire lands in the
/// global total AND the per-site ledger (which survives `clear()`), a
/// depth-cap shed lands in `nq_shed_total`, and all of it renders as
/// grammar-valid Prometheus with the labelled site family.
#[test]
fn fault_counters_land_on_every_scrape_surface() {
    let _g = seq();
    faults::clear();
    let before = Snapshot::gather(&[]);
    let site_of = |s: &Snapshot| {
        s.faults_by_site
            .iter()
            .find(|(site, _)| site == "test.telemetry")
            .map(|&(_, n)| n)
            .unwrap_or(0)
    };
    let site_before = site_of(&before);

    faults::arm("test.telemetry", FaultSpec::always(FaultMode::Err));
    assert!(faults::fail_point("test.telemetry").is_err());
    assert!(faults::fail_point("test.telemetry").is_err());
    faults::clear();
    // disarmed: the site no longer fires, but its ledger survives
    assert!(faults::fail_point("test.telemetry").is_ok());

    // a depth-capped scheduler sheds the overflow push
    let s: FairScheduler<&str> = FairScheduler::with_infer_cap(&[1], 1);
    assert_eq!(s.push_infer(0, "a"), Admit::Queued);
    assert_eq!(s.push_infer(0, "b"), Admit::Shed);

    let after = Snapshot::gather(&[]);
    let d = |name: &str| after.counter(name).unwrap() - before.counter(name).unwrap();
    assert_eq!(d("nq_faults_fired_total"), 2);
    assert_eq!(d("nq_shed_total"), 1);
    assert_eq!(site_of(&after) - site_before, 2, "per-site ledger is exact");
    assert!(
        after.counter("nq_worker_panics_total").is_some(),
        "panic counter is always scrapeable (chaos.rs moves it)"
    );

    let prom = after.prometheus();
    validate_prometheus(&prom).unwrap();
    assert!(prom.contains(&format!(
        "nq_faults_site_fired_total{{site=\"test.telemetry\"}} {}",
        site_of(&after)
    )));

    // the wire roundtrip carries the ledger unchanged
    let back = Snapshot::from_json(&after.to_json()).unwrap();
    assert_eq!(back.faults_by_site, after.faults_by_site);
}

/// The per-tenant breaker state rides the tenant snapshot: gauge value,
/// Prometheus family, and the `top` BRK column all show the same state.
#[test]
fn breaker_state_reaches_all_three_surfaces() {
    let m = Arc::new(Metrics::default());
    m.breaker_state.store(1, Ordering::Relaxed); // open
    let snap = Snapshot::gather(&[("edge".to_string(), Arc::clone(&m))]);
    assert_eq!(snap.tenant("edge").unwrap().breaker_state, 1);
    let prom = snap.prometheus();
    validate_prometheus(&prom).unwrap();
    assert!(prom.contains("nq_tenant_breaker_state{tenant=\"edge\"} 1"));
    assert!(snap.top_table().contains("open"));
    let back = Snapshot::from_json(&snap.to_json()).unwrap();
    assert_eq!(back.tenant("edge").unwrap().breaker_state, 1);
}

/// With the ring enabled, the scripted store events land as typed trace
/// entries and ride along in the snapshot.
#[test]
fn enabled_trace_captures_store_events() {
    let _g = seq();
    registry().trace.clear();
    registry().trace.enable();

    let a = archive(0xACE0);
    let b = archive(0xACE1);
    a.part_bit().unwrap(); // PageIn (section A)
    let budget = StoreBudget::new(a.section_b_bytes());
    budget.attach_b("ta", &a).unwrap(); // PageIn (section B)
    budget.attach_b("tb", &b).unwrap(); // Eviction of ta + PageIn
    budget.release_b("tb"); // PageOut

    registry().trace.disable();
    let kinds: Vec<TraceKind> = registry().trace.tail(64).iter().map(|e| e.kind).collect();
    assert!(kinds.contains(&TraceKind::PageIn), "{kinds:?}");
    assert!(kinds.contains(&TraceKind::PageOut), "{kinds:?}");
    assert!(kinds.contains(&TraceKind::Eviction), "{kinds:?}");
    let evict = registry()
        .trace
        .tail(64)
        .into_iter()
        .find(|e| e.kind == TraceKind::Eviction)
        .unwrap();
    assert!(evict.detail.contains("ta"), "victim named: {}", evict.detail);

    // the snapshot carries the tail and survives its wire roundtrip
    let snap = Snapshot::gather(&[]);
    assert!(snap.trace.iter().any(|e| e.kind == TraceKind::Eviction));
    let back = Snapshot::from_json(&snap.to_json()).unwrap();
    assert_eq!(back.trace, snap.trace);
    registry().trace.clear();
}
