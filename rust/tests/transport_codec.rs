//! Incremental frame codec (`FrameReader`/`FrameWriter`) vs the
//! blocking codec: any byte-level split of the stream must parse to the
//! same frames, the writer must emit byte-identical wire form, and an
//! oversized length header must be refused as soon as it is readable.

use std::io::{Cursor, Write};

use nestquant::transport::{
    recv_frame, send_frame, Frame, FrameKind, FrameReader, FrameWriter, Meter, MAX_FRAME,
};

/// Wire bytes of `frame` as the blocking codec produces them.
fn blocking_encode(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    send_frame(&mut buf, frame, &Meter::default()).unwrap();
    buf
}

fn sample_frames() -> Vec<Frame> {
    vec![
        Frame {
            kind: FrameKind::Control,
            name: "advice".into(),
            payload: b"upgrade".to_vec(),
        },
        // empty name + empty payload: the 15-byte minimum frame
        Frame {
            kind: FrameKind::Ack,
            name: String::new(),
            payload: Vec::new(),
        },
        Frame {
            kind: FrameKind::ModelDelta,
            name: "cnn_m_n8h4".into(),
            payload: (0..=255u8).collect(),
        },
    ]
}

#[test]
fn every_byte_boundary_split_parses_identically() {
    for frame in sample_frames() {
        let wire = blocking_encode(&frame);
        for split in 0..=wire.len() {
            let mut reader = FrameReader::new();
            reader.feed(&wire[..split]).unwrap();
            if split < wire.len() {
                assert!(
                    reader.next_frame().unwrap().is_none(),
                    "frame complete after only {split}/{} bytes",
                    wire.len()
                );
                reader.feed(&wire[split..]).unwrap();
            }
            let (got, got_wire) = reader.next_frame().unwrap().expect("complete frame");
            assert_eq!(got, frame, "split at byte {split}");
            assert_eq!(got_wire, wire.len() as u64);
            assert_eq!(reader.buffered(), 0);
        }
    }
}

#[test]
fn byte_at_a_time_stream_yields_every_frame_in_order() {
    let frames = sample_frames();
    let stream: Vec<u8> = frames.iter().flat_map(|f| blocking_encode(f)).collect();

    let mut reader = FrameReader::new();
    let mut got = Vec::new();
    for &b in &stream {
        reader.feed(&[b]).unwrap();
        while let Some((frame, _)) = reader.next_frame().unwrap() {
            got.push(frame);
        }
    }
    assert_eq!(got, frames);
    assert_eq!(reader.buffered(), 0, "no stray bytes after the last frame");
}

#[test]
fn need_counts_down_to_frame_completion() {
    let frame = &sample_frames()[0];
    let wire = blocking_encode(frame);
    let mut reader = FrameReader::new();
    for (i, &b) in wire.iter().enumerate() {
        let need = reader.need();
        assert!(need > 0, "need() zero with only {i} bytes fed");
        assert!(need <= wire.len() - i);
        reader.feed(&[b]).unwrap();
    }
    assert_eq!(reader.need(), 0);
}

#[test]
fn oversized_length_header_is_refused_when_readable() {
    // magic + kind + name_len=1 + name + an 8-byte length just past the
    // cap: the reader must fail on feeding the header, before any
    // payload byte arrives
    let mut header = Vec::new();
    header.extend_from_slice(&0x4E51_5458u32.to_le_bytes());
    header.push(4); // Control
    header.extend_from_slice(&1u16.to_le_bytes());
    header.push(b'x');
    header.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());

    let mut reader = FrameReader::new();
    let err = reader.feed(&header).unwrap_err();
    assert!(
        err.to_string().contains("frame too large"),
        "unexpected error: {err:#}"
    );

    // exactly MAX_FRAME is within protocol bounds: the same header with
    // the cap value must be accepted (the payload then streams in)
    let len_at = header.len() - 8;
    header[len_at..].copy_from_slice(&MAX_FRAME.to_le_bytes());
    let mut reader = FrameReader::new();
    reader.feed(&header).unwrap();
    assert!(reader.next_frame().unwrap().is_none());
}

#[test]
fn poisoned_prefix_fails_eagerly() {
    let mut reader = FrameReader::new();
    let err = reader.feed(b"oops").unwrap_err();
    assert!(err.to_string().contains("bad frame magic"));

    let mut reader = FrameReader::new();
    let mut bytes = 0x4E51_5458u32.to_le_bytes().to_vec();
    bytes.push(9); // no such kind
    let err = reader.feed(&bytes).unwrap_err();
    assert!(err.to_string().contains("unknown frame kind"));
}

#[test]
fn writer_matches_blocking_codec_byte_for_byte() {
    let frames = sample_frames();
    let expected: Vec<u8> = frames.iter().flat_map(|f| blocking_encode(f)).collect();

    let meter = Meter::default();
    let mut writer = FrameWriter::new();
    for f in &frames {
        writer.queue(f).unwrap();
    }
    let mut sink = Vec::new();
    assert!(writer.flush_to(&mut sink, &meter).unwrap());
    assert!(writer.is_empty());
    assert_eq!(sink, expected);
    assert_eq!(meter.snapshot().0, expected.len() as u64);
}

/// A sink that accepts at most 3 bytes per call and interposes a
/// `WouldBlock` between accepting calls, like a congested socket.
struct Throttled {
    out: Vec<u8>,
    ready: bool,
}

impl Write for Throttled {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if !self.ready {
            self.ready = true;
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        self.ready = false;
        let n = buf.len().min(3);
        self.out.extend_from_slice(&buf[..n]);
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn interleaved_queue_and_throttled_flush_keeps_frames_intact() {
    let frames = sample_frames();
    let wire_a = blocking_encode(&frames[0]);

    let meter = Meter::default();
    let mut writer = FrameWriter::new();
    let mut sink = Throttled {
        out: Vec::new(),
        ready: false,
    };

    writer.queue(&frames[0]).unwrap();
    // flush part of frame 0, then queue the rest mid-stream — frames
    // must come out whole and in order regardless
    assert!(!writer.flush_to(&mut sink, &meter).unwrap()); // WouldBlock
    assert!(!writer.flush_to(&mut sink, &meter).unwrap()); // 3 bytes out
    assert!(sink.out.len() < wire_a.len());
    assert_eq!(meter.snapshot().0, 0, "no frame fully flushed yet");
    writer.queue(&frames[1]).unwrap();
    writer.queue(&frames[2]).unwrap();

    let mut rounds = 0;
    while !writer.flush_to(&mut sink, &meter).unwrap() {
        rounds += 1;
        assert!(rounds < 10_000, "flush never completed");
    }
    let expected: Vec<u8> = frames.iter().flat_map(|f| blocking_encode(f)).collect();
    assert_eq!(sink.out, expected);
    assert_eq!(meter.snapshot().0, expected.len() as u64);

    // the blocking reader consumes the throttled writer's stream
    let mut cursor = Cursor::new(sink.out);
    let rx = Meter::default();
    for f in &frames {
        let (got, _) = recv_frame(&mut cursor, &rx).unwrap();
        assert_eq!(&got, f);
    }
    assert_eq!(rx.snapshot().1, expected.len() as u64);
}
